PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-fast bench-quick bench-overhead lint dryrun-smoke

# tier-1 verify (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# skip the multi-minute dry-run end-to-end test
test-fast:
	$(PY) -m pytest -x -q -m "not slow"

bench-quick:
	$(PY) -m benchmarks.run --quick

# regenerate the committed BENCH_safeguard_overhead.json baseline
bench-overhead:
	$(PY) -m benchmarks.run --quick --only overhead

lint:
	$(PY) -m compileall -q src tests benchmarks examples
	@! grep -rn "breakpoint()\|pdb.set_trace" src tests benchmarks examples

dryrun-smoke:
	$(PY) -m repro.launch.dryrun --arch mamba2-130m --shape train_4k \
	    --out /tmp/dryrun_smoke
