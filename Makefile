PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-fast bench-quick bench-overhead bench-regress \
	campaign-smoke adaptive-smoke defense-smoke hetero-smoke \
	saddle-smoke lint lint-fast lint-baselines dryrun-smoke obs-smoke \
	live-smoke

# tier-1 verify (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# skip the multi-minute dry-run end-to-end test
test-fast:
	$(PY) -m pytest -x -q -m "not slow"

bench-quick:
	$(PY) -m benchmarks.run --quick

# regenerate the committed BENCH_safeguard_overhead.json baseline
bench-overhead:
	$(PY) -m benchmarks.run --quick --only overhead

# benchmark regression gate (DESIGN.md §17): re-measure the
# machine-independent metrics of every committed BENCH_*.json baseline
# and fail on tolerance breaks
bench-regress:
	$(PY) -m benchmarks.regress --check

# the CI campaign step: run the quick Table-1 grid, assert the store resumes
campaign-smoke:
	$(PY) -m repro.campaign.run --campaign table1 --quick --seeds 2
	$(PY) -m repro.campaign.run --campaign table1 --quick --seeds 2 \
	    | grep -q "new_cells=0"

# the CI adaptive step: feedback-coupled adversaries end-to-end (DESIGN.md §11)
adaptive-smoke:
	$(PY) -m repro.campaign.run --campaign adaptive --quick --seeds 2

# the CI defense-zoo step (DESIGN.md §12): new stateful defenses x
# {variance, adaptive_flip}, then assert the store resumes with 0 new cells
defense-smoke:
	$(PY) -m repro.campaign.run --campaign defense --quick --seeds 2
	$(PY) -m repro.campaign.run --campaign defense --quick --seeds 2 \
	    | grep -q "new_cells=0"

# the CI heterogeneity step (DESIGN.md §13): non-IID worker models x
# defenses (incl. bucketing), then assert the store resumes with 0 new cells
hetero-smoke:
	$(PY) -m repro.campaign.run --campaign hetero --quick --seeds 1
	$(PY) -m repro.campaign.run --campaign hetero --quick --seeds 1 \
	    | grep -q "new_cells=0"

# the CI saddle step (DESIGN.md §14): planted-saddle testbed x defense x
# attack with the second-order trace lane, then assert the store resumes
saddle-smoke:
	$(PY) -m repro.campaign.run --campaign saddle --quick --seeds 1
	$(PY) -m repro.campaign.run --campaign saddle --quick --seeds 1 \
	    | grep -q "new_cells=0"

# the CI observability step (DESIGN.md §15): tiny traced campaign ->
# forensics report; assert (1) stored event logs bit-match events
# recomputed from the raw .npz trace arrays, (2) a resume run leaves the
# trace sidecars byte-identical
obs-smoke:
	rm -rf /tmp/obs-smoke && mkdir -p /tmp/obs-smoke
	$(PY) -m repro.campaign.run --campaign smoke --quick --seeds 1 \
	    --root /tmp/obs-smoke --store-traces
	$(PY) -m repro.obs.report --campaign smoke --root /tmp/obs-smoke \
	    --check-events
	$(PY) -m repro.obs.report --campaign smoke --root /tmp/obs-smoke \
	    --out /tmp/obs-smoke/report.md && head -8 /tmp/obs-smoke/report.md
	md5sum /tmp/obs-smoke/smoke/traces/*.npz > /tmp/obs-smoke/traces.md5
	$(PY) -m repro.campaign.run --campaign smoke --quick --seeds 1 \
	    --root /tmp/obs-smoke --store-traces | grep -q "new_cells=0"
	md5sum -c --quiet /tmp/obs-smoke/traces.md5

# the CI live-telemetry step (DESIGN.md §17): tapped smoke campaign ->
# per-cell heartbeat JSONL under <store>/live/; assert (1) heartbeats
# exist and render, (2) the clean lane raises zero alerts while the
# variance-attack lane raises an eviction storm, (3) a resume run
# leaves the heartbeat files byte-identical, (4) the benchmark
# regression gate holds on the live-overhead baseline
live-smoke:
	rm -rf /tmp/live-smoke && mkdir -p /tmp/live-smoke
	$(PY) -m repro.campaign.run --campaign live --quick --seeds 1 \
	    --tap-every 10 --root /tmp/live-smoke
	test -n "$$(ls /tmp/live-smoke/live/live/*.jsonl)"
	$(PY) -m repro.obs.live tail --root /tmp/live-smoke \
	    --campaign live --once
	$(PY) -m repro.obs.live alerts --root /tmp/live-smoke \
	    --campaign live \
	    --expect-clean none- --expect-clean variance-mean \
	    --expect eviction_storm:variance-safeguard_double
	md5sum /tmp/live-smoke/live/live/*.jsonl > /tmp/live-smoke/beats.md5
	$(PY) -m repro.campaign.run --campaign live --quick --seeds 1 \
	    --tap-every 10 --root /tmp/live-smoke \
	    | grep "new_cells=0" >/dev/null
	md5sum -c --quiet /tmp/live-smoke/beats.md5
	$(PY) -m benchmarks.regress --check --only live

# static analysis (DESIGN.md §16): ruff (style subset, pyproject.toml)
# when available + the repo's JAX-aware analyzer (tier 1 AST passes,
# tier 2 jaxpr passes against the committed baselines)
lint:
	@if command -v ruff >/dev/null 2>&1; then ruff check .; \
	    else echo "lint: ruff not installed; skipping style pass"; fi
	$(PY) -m repro.lint

# AST passes only (~10s) — the tier-2 jaxpr diff traces all campaign
# programs (~2 min); run full `make lint` before pushing
lint-fast:
	$(PY) -m repro.lint --tier 1

# regenerate the committed jaxpr-hash / rng-count / Scenario-field
# baselines after an intentional program-structure change
lint-baselines:
	$(PY) -m repro.lint --update-baselines

dryrun-smoke:
	$(PY) -m repro.launch.dryrun --arch mamba2-130m --shape train_4k \
	    --out /tmp/dryrun_smoke
