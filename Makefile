PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-fast bench-quick bench-overhead campaign-smoke \
	adaptive-smoke defense-smoke hetero-smoke saddle-smoke lint \
	dryrun-smoke obs-smoke

# tier-1 verify (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# skip the multi-minute dry-run end-to-end test
test-fast:
	$(PY) -m pytest -x -q -m "not slow"

bench-quick:
	$(PY) -m benchmarks.run --quick

# regenerate the committed BENCH_safeguard_overhead.json baseline
bench-overhead:
	$(PY) -m benchmarks.run --quick --only overhead

# the CI campaign step: run the quick Table-1 grid, assert the store resumes
campaign-smoke:
	$(PY) -m repro.campaign.run --campaign table1 --quick --seeds 2
	$(PY) -m repro.campaign.run --campaign table1 --quick --seeds 2 \
	    | grep -q "new_cells=0"

# the CI adaptive step: feedback-coupled adversaries end-to-end (DESIGN.md §11)
adaptive-smoke:
	$(PY) -m repro.campaign.run --campaign adaptive --quick --seeds 2

# the CI defense-zoo step (DESIGN.md §12): new stateful defenses x
# {variance, adaptive_flip}, then assert the store resumes with 0 new cells
defense-smoke:
	$(PY) -m repro.campaign.run --campaign defense --quick --seeds 2
	$(PY) -m repro.campaign.run --campaign defense --quick --seeds 2 \
	    | grep -q "new_cells=0"

# the CI heterogeneity step (DESIGN.md §13): non-IID worker models x
# defenses (incl. bucketing), then assert the store resumes with 0 new cells
hetero-smoke:
	$(PY) -m repro.campaign.run --campaign hetero --quick --seeds 1
	$(PY) -m repro.campaign.run --campaign hetero --quick --seeds 1 \
	    | grep -q "new_cells=0"

# the CI saddle step (DESIGN.md §14): planted-saddle testbed x defense x
# attack with the second-order trace lane, then assert the store resumes
saddle-smoke:
	$(PY) -m repro.campaign.run --campaign saddle --quick --seeds 1
	$(PY) -m repro.campaign.run --campaign saddle --quick --seeds 1 \
	    | grep -q "new_cells=0"

# the CI observability step (DESIGN.md §15): tiny traced campaign ->
# forensics report; assert (1) stored event logs bit-match events
# recomputed from the raw .npz trace arrays, (2) a resume run leaves the
# trace sidecars byte-identical
obs-smoke:
	rm -rf /tmp/obs-smoke && mkdir -p /tmp/obs-smoke
	$(PY) -m repro.campaign.run --campaign smoke --quick --seeds 1 \
	    --root /tmp/obs-smoke --store-traces
	$(PY) -m repro.obs.report --campaign smoke --root /tmp/obs-smoke \
	    --check-events
	$(PY) -m repro.obs.report --campaign smoke --root /tmp/obs-smoke \
	    --out /tmp/obs-smoke/report.md && head -8 /tmp/obs-smoke/report.md
	md5sum /tmp/obs-smoke/smoke/traces/*.npz > /tmp/obs-smoke/traces.md5
	$(PY) -m repro.campaign.run --campaign smoke --quick --seeds 1 \
	    --root /tmp/obs-smoke --store-traces | grep -q "new_cells=0"
	md5sum -c --quiet /tmp/obs-smoke/traces.md5

lint:
	$(PY) -m compileall -q src tests benchmarks examples
	@! grep -rn "breakpoint()\|pdb.set_trace" src tests benchmarks examples

dryrun-smoke:
	$(PY) -m repro.launch.dryrun --arch mamba2-130m --shape train_4k \
	    --out /tmp/dryrun_smoke
