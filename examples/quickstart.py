"""Quickstart: Byzantine-resilient training in ~40 lines.

Trains a small MLP on a synthetic teacher-student task with 10 workers of
which 4 are Byzantine sign-flippers, defended by SafeguardSGD.  Watch the
safeguard evict exactly the 4 attackers within the first window.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core import SafeguardConfig
from repro.core import attacks as atk_lib
from repro.data import tasks
from repro.optim import make_optimizer
from repro.train import Trainer, init_train_state, make_train_step

M, N_BYZ = 10, 4                                  # paper: alpha = 0.4


def main():
    byz_mask = jnp.arange(M) < N_BYZ
    task = tasks.make_teacher_task(d_in=32, d_hidden=64, n_classes=10)
    attack = atk_lib.make_registry()["sign_flip"]
    sg_cfg = SafeguardConfig(m=M, T0=20, T1=120, threshold_floor=0.1)

    opt = make_optimizer(TrainConfig(lr=0.1))
    params = tasks.student_init(task)
    state = init_train_state(params, opt, sg_cfg=sg_cfg, attack=attack)
    step = make_train_step(tasks.mlp_loss, opt, byz_mask=byz_mask,
                           sg_cfg=sg_cfg, attack=attack)

    data = tasks.teacher_batches(task, batch=100, m=M)
    trainer = Trainer(state, step, data, log_every=50, name="quickstart")
    trainer.run(300)

    good = trainer.state.sg_state.good
    eval_batch = tasks.teacher_batch(task, jax.random.PRNGKey(99), 4000)
    acc = tasks.mlp_accuracy(trainer.state.params, eval_batch)
    print(f"\nfinal good mask: {good}   (workers 0-3 are Byzantine)")
    print(f"caught {int((byz_mask & ~good).sum())}/4 attackers, "
          f"evicted {int((~byz_mask & ~good).sum())} honest workers")
    print(f"test accuracy: {float(acc):.3f}")


if __name__ == "__main__":
    main()
