"""Batched serving example: prefill + KV-cache greedy decoding on the
tinyllama-family reduced config, demonstrating the same serve_step that the
decode_32k / long_500k dry runs lower at 256/512-chip scale.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models import transformer as T
from repro.train.serve import generate


def main():
    cfg = C.get_smoke("tinyllama-1.1b")
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)

    batch, prompt_len, gen = 8, 48, 32
    prompt = jax.random.randint(key, (batch, prompt_len), 0,
                                cfg.vocab_size, dtype=jnp.int32)

    t0 = time.time()
    toks = generate(params, cfg, prompt, n_tokens=gen,
                    max_seq=prompt_len + gen)
    toks.block_until_ready()
    compile_and_run = time.time() - t0

    t0 = time.time()
    toks = generate(params, cfg, prompt, n_tokens=gen,
                    max_seq=prompt_len + gen)
    toks.block_until_ready()
    steady = time.time() - t0

    print(f"batch={batch} prompt={prompt_len} generated={gen}")
    print(f"first call (incl. compile): {compile_and_run:.2f}s; "
          f"steady state: {steady:.3f}s "
          f"({batch * gen / steady:.0f} tok/s)")
    print("sample:", toks[0].tolist())

    # sliding-window variant handles arbitrarily long contexts with a
    # bounded cache — same path the long_500k dry run exercises
    cfg_swa = C.get_smoke("tinyllama-1.1b-swa")
    params_swa = T.init_params(cfg_swa, key)
    toks = generate(params_swa, cfg_swa, prompt, n_tokens=gen,
                    max_seq=prompt_len + gen)
    print("swa sample:", toks[0, :8].tolist())


if __name__ == "__main__":
    main()
