"""End-to-end driver: train a ~100M-parameter llama-family LM for a few
hundred steps under a Byzantine variance attack, defended by SafeguardSGD.

By default runs a ~12M model so the example finishes in minutes on CPU;
pass ``--large`` for the ~100M configuration (same code path, longer run).

    PYTHONPATH=src python examples/train_lm.py [--large] [--steps 300]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import SafeguardConfig
from repro.core import attacks as atk_lib
from repro.data import pipeline as data_lib
from repro.models import transformer as T
from repro.optim import make_optimizer
from repro.train import Trainer, init_train_state, make_train_step

M, N_BYZ = 8, 3


def model_config(large: bool) -> ModelConfig:
    if large:   # ~100M params
        return ModelConfig(name="lm-100m", arch_type="dense", n_layers=12,
                           d_model=768, n_heads=12, n_kv_heads=4,
                           d_ff=2048, vocab_size=8192)
    return ModelConfig(name="lm-12m", arch_type="dense", n_layers=4,
                       d_model=256, n_heads=8, n_kv_heads=2, d_ff=1024,
                       vocab_size=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--large", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    cfg = model_config(args.large)
    print(f"model: {cfg.name} "
          f"({cfg.param_count() / 1e6:.1f}M params), m={M} workers, "
          f"{N_BYZ} Byzantine (variance attack)")

    byz_mask = jnp.arange(M) < N_BYZ
    attack = atk_lib.make_registry()["variance"]
    sg_cfg = SafeguardConfig(m=M, T0=25, T1=100, threshold_floor=1.0)
    opt = make_optimizer(TrainConfig(lr=0.02, optimizer="adam"))

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    loss = lambda p, b: T.loss_fn(p, cfg, b)
    state = init_train_state(params, opt, sg_cfg=sg_cfg, attack=attack)
    step = make_train_step(loss, opt, byz_mask=byz_mask, sg_cfg=sg_cfg,
                           attack=attack)

    data = data_lib.lm_batches(cfg.vocab_size, args.batch, args.seq, m=M)
    trainer = Trainer(state, step, data, log_every=25, name=cfg.name)
    trainer.run(args.steps)

    good = trainer.state.sg_state.good
    print(f"\nfinal good mask: {good}")
    print(f"caught {int((byz_mask & ~good).sum())}/{N_BYZ} attackers; "
          f"honest evicted: {int((~byz_mask & ~good).sum())}")
    print(f"final honest loss: {trainer.history[-1]['honest_loss']:.4f} "
          f"(init ~{jnp.log(cfg.vocab_size):.2f})")


if __name__ == "__main__":
    main()
