"""Paper Figure 2(a) demo: watch the detection statistic separate.

Runs SafeguardSGD against the variance attack [Baruch et al. 2019] with
eviction disabled, printing ||B_i - B_med|| for one honest and one
Byzantine worker: honest drifts ~sqrt(t) (martingale concentration),
Byzantine drifts ~linearly — the separation that historyless defenses
cannot see.

    PYTHONPATH=src python examples/detection_demo.py
"""

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core import SafeguardConfig, safeguard_step
from repro.core import attacks as atk_lib
from repro.data import tasks
from repro.optim import make_optimizer
from repro.train import init_train_state

M, N_BYZ = 10, 4


def main():
    task = tasks.make_teacher_task()
    byz = jnp.arange(M) < N_BYZ
    attack = atk_lib.make_variance_attack(z_max=1.5)
    # windows/threshold set huge => statistic observable, nobody evicted
    sg_cfg = SafeguardConfig(m=M, T0=10 ** 6, T1=10 ** 6,
                             threshold_floor=10 ** 6)
    opt = make_optimizer(TrainConfig(lr=0.05))
    state = init_train_state(tasks.student_init(task), opt, sg_cfg=sg_cfg)

    data = tasks.teacher_batches(task, 100, m=M)
    vg = jax.value_and_grad(tasks.mlp_loss)
    astate = None
    print(f"{'step':>6} {'byzantine':>12} {'honest':>12} {'ratio':>8}")
    for t in range(201):
        batch = next(data)
        _, grads = jax.vmap(lambda wb: vg(state.params, wb))(batch)
        grads, astate = attack(grads, byz, astate, state.step,
                               jax.random.PRNGKey(t))
        sg_state, agg, info = safeguard_step(state.sg_state, grads, sg_cfg)
        params, opt_state = opt.update(agg, state.opt_state, state.params,
                                       state.step)
        state = state.__class__(params=params, opt_state=opt_state,
                                defense_state=sg_state, attack_state=astate,
                                step=state.step + 1, rng=state.rng)
        if t % 25 == 0:
            d = info["dist_to_med_B"]
            b, h = float(d[0]), float(d[6])
            print(f"{t:>6} {b:>12.4f} {h:>12.4f} {b / max(h, 1e-9):>8.1f}x")

    print("\nByzantine drift grows linearly in t; honest drift ~sqrt(t).")
    print("With realistic windows the safeguard evicts all four attackers")
    print("(see tests/test_safeguard.py::test_variance_attack_caught...).")


if __name__ == "__main__":
    main()
