"""Saddle-escape time distributions (DESIGN.md §14): the theorem-level
view of SafeguardSGD.  Runs the planted-saddle grid through the campaign
engine — {clean, saddle_push-attacked} x {safeguard_double + sgd_escape
noise, undefended mean} per task kind over several seeds — and reports
the escape-step distribution per cell next to the predicted budget of
``data.saddle.escape_budget``.

Expected table: every safeguard cell escapes within the budget (finite
``escape_step``), while the undefended mean under ``saddle_push`` never
escapes (``escape_step = -1``: the colluders cancel the honest escape
component and the iterate stays pinned at the saddle).

Writes ``experiments/bench/saddle_escape.json`` and a markdown table
``experiments/bench/saddle_escape.md``.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

from benchmarks import common
from repro.campaign import engine
from repro.campaign.scenario import scenario_id
from repro.data import saddle as sad_lib

STEPS = 400
SEEDS = 3
GAP, NOISE_R, NU, LR, D = 1.0, 0.05, 0.1, 0.1, 16

CELLS = [
    # (label, defense, attack, perturb)
    ("safeguard/clean", "safeguard_double", "none", "sgd_escape"),
    ("safeguard/saddle_push", "safeguard_double", "saddle_push",
     "sgd_escape"),
    ("mean/clean", "mean", "none", "sgd_escape"),
    ("mean/saddle_push", "mean", "saddle_push", "none"),
]


def run(steps: int = STEPS, seeds: int = SEEDS,
        out_dir: str = "experiments/bench") -> List[Dict]:
    rows = []
    for kind in sad_lib.SADDLE_TASKS:
        stask = sad_lib.make_saddle_task(D, kind)
        budget = sad_lib.escape_budget(stask, GAP, LR, u0=LR * NU / 2)
        scns, labels = [], {}
        for label, dfn, atk, pert in CELLS:
            for seed in range(seeds):
                s = common.saddle_scenario_for(
                    kind, steps=steps, seed=seed, d=D, gap=GAP,
                    noise_r=NOISE_R, lr=LR, defense_name=dfn,
                    attack_name=atk, perturb=pert, escape_nu=NU,
                    adapt_init=1.0)
                scns.append(s)
                labels[scenario_id(s)] = label
        res = engine.run_scenarios(scns, verbose=True)
        per_cell: Dict[str, List[int]] = {}
        for s in scns:
            rec = res[scenario_id(s)]
            per_cell.setdefault(labels[scenario_id(s)], []).append(
                rec["escape_step"])
        for label, _, _, _ in CELLS:
            esc = per_cell[label]
            fin = [e for e in esc if e >= 0]
            row = {"task": kind, "cell": label, "budget": budget,
                   "seeds": seeds,
                   "frac_escaped": len(fin) / len(esc),
                   "escape_mean": float(np.mean(fin)) if fin else -1,
                   "escape_min": min(fin) if fin else -1,
                   "escape_max": max(fin) if fin else -1}
            rows.append(row)
            print(f"saddle_escape,{kind},{label},"
                  f"frac_escaped={row['frac_escaped']:.2f},"
                  f"mean={row['escape_mean']:.0f},"
                  f"max={row['escape_max']},budget={budget}")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "saddle_escape.json"), "w") as f:
        json.dump(rows, f, indent=1)
    hdr = ("| task | cell | escaped | mean | min | max | budget |\n"
           "|---|---|---|---|---|---|---|\n")
    body = "".join(
        f"| {r['task']} | {r['cell']} | {r['frac_escaped']:.2f} "
        f"| {r['escape_mean']:.0f} | {r['escape_min']} | {r['escape_max']} "
        f"| {r['budget']} |\n" for r in rows)
    with open(os.path.join(out_dir, "saddle_escape.md"), "w") as f:
        f.write(hdr + body)
    return rows


if __name__ == "__main__":
    run()
