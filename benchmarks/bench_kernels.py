"""Kernel micro-benchmarks: Pallas (interpret mode — CPU emulation, NOT a
TPU timing) vs the pure-jnp XLA reference, plus the analytic FLOP count
each kernel would issue on the MXU."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels.safeguard_filter import pairwise_sqdist
from repro.kernels.safeguard_filter import ref as sf_ref
from repro.kernels.robust_agg import coord_median
from repro.kernels.robust_agg import ref as ra_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention import ref as fa_ref


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(out_dir: str = "experiments/bench"):
    key = jax.random.PRNGKey(0)
    key_sf, key_med, key = jax.random.split(key, 3)
    rows = []

    m, d = 16, 65536
    a = jax.random.normal(key_sf, (m, d), jnp.bfloat16)
    us_k = _time(lambda x: pairwise_sqdist(x), a)
    us_r = _time(jax.jit(sf_ref.pairwise_sqdist), a)
    flops = 2 * m * m * d
    rows.append({"kernel": "safeguard_filter", "interp_us": us_k,
                 "ref_us": us_r, "flops": flops})
    print(f"bench_kernels,safeguard_filter,{us_k:.0f}us(interp),"
          f"{us_r:.0f}us(ref),{flops:.2e}flops")

    g = jax.random.normal(key_med, (10, 65536))
    us_k = _time(lambda x: coord_median(x), g)
    us_r = _time(jax.jit(ra_ref.coord_median), g)
    rows.append({"kernel": "robust_agg_median", "interp_us": us_k,
                 "ref_us": us_r})
    print(f"bench_kernels,robust_agg_median,{us_k:.0f}us(interp),"
          f"{us_r:.0f}us(ref)")

    B, H, K, L, D = 1, 4, 2, 512, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, L, D), jnp.bfloat16)
    k_ = jax.random.normal(ks[1], (B, K, L, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, K, L, D), jnp.bfloat16)
    us_k = _time(lambda *x: flash_attention(*x, block_q=128, block_k=128),
                 q, k_, v)
    us_r = _time(jax.jit(fa_ref.attention), q, k_, v)
    flops = 4 * B * H * L * L * D // 2      # causal
    rows.append({"kernel": "flash_attention", "interp_us": us_k,
                 "ref_us": us_r, "flops": flops})
    print(f"bench_kernels,flash_attention,{us_k:.0f}us(interp),"
          f"{us_r:.0f}us(ref),{flops:.2e}flops")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "kernels.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    run()
