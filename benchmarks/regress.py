"""Benchmark regression gate: diff fresh runs against the committed
``BENCH_*.json`` trajectory with per-metric tolerances.

The repo commits four benchmark baselines at the root —
``BENCH_trace_overhead.json``, ``BENCH_safeguard_overhead.json``,
``BENCH_campaign_throughput.json``, ``BENCH_live_overhead.json`` — each
carrying a measured claim (capture <5%, flat engine beats stacked, vmap
beats the loop with zero acc drift, tap_every=50 <2%).  Absolute
timings are machine weather; what must NOT regress are the
machine-independent derived metrics: overhead *fractions*, speedup
*ratios*, ``claim_holds`` booleans, drift ceilings.  This module is the
registry of those metrics and their tolerances, and the CI entry point
that re-measures them:

    PYTHONPATH=src python -m benchmarks.regress --check [--only live,...]

``--check`` re-runs each benchmark in quick mode into a scratch
directory (the committed baselines are never overwritten) and compares.
``--against DIR`` skips the re-run and diffs pre-computed records from
``DIR`` (the offline path unit tests use).  Exit code 1 on any failed
comparison, with one ``regress,...`` CSV line per metric either way.

Comparison kinds:

  ``bool``     fresh value must equal the committed one
  ``abs``      ``|fresh - base| <= tol``
  ``ceiling``  both committed and fresh must be ``<= tol`` (re-verifies
               an absolute claim and that the committed file still
               honors it)
  ``floor``    both committed and fresh must be ``>= tol``
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional

REPO_ROOT = Path(__file__).resolve().parent.parent

KINDS = ("bool", "abs", "ceiling", "floor")


@dataclass(frozen=True)
class Check:
    """One guarded metric of a baseline record.

    ``extract(record) -> {label: value}`` pulls the metric(s); the
    default reads ``record[metric]`` as a single unlabeled value.
    Labels present on only one side (e.g. the model size the full run
    measures but quick mode skips) are reported and skipped, not
    failed."""
    metric: str
    kind: str
    tol: float = 0.0
    extract: Optional[Callable[[Dict], Dict[str, float]]] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown check kind {self.kind!r}")

    def values(self, record: Dict) -> Dict[str, float]:
        if self.extract is not None:
            return self.extract(record)
        return {"": record[self.metric]}


@dataclass(frozen=True)
class Suite:
    """One committed baseline: how to re-measure it and what to hold."""
    baseline: str                       # file name at the repo root
    fresh: Callable[[str], Dict]        # out_path -> fresh record
    checks: List[Check] = field(default_factory=list)


def _speedup_entries(record: Dict) -> Dict[str, float]:
    return {f"d={e['d']}": e["flat_speedup_vs_stacked"]
            for e in record.get("entries", [])
            if "flat_speedup_vs_stacked" in e}


def _fresh_trace(out_path: str) -> Dict:
    from benchmarks import trace_overhead
    return trace_overhead.run(steps=60, repeats=3, out_path=out_path)


def _fresh_safeguard(out_path: str) -> Dict:
    from benchmarks import overhead
    out_dir = os.path.dirname(out_path) or "."
    overhead.run(out_dir=out_dir, quick=True, baseline_path=out_path)
    with open(out_path) as f:
        return json.load(f)


def _fresh_campaign(out_path: str) -> Dict:
    from benchmarks import campaign_throughput
    out_dir = os.path.dirname(out_path) or "."
    campaign_throughput.run(out_dir=out_dir, quick=True,
                            baseline_path=out_path)
    with open(out_path) as f:
        return json.load(f)


def _fresh_live(out_path: str) -> Dict:
    from benchmarks import live_overhead
    # steps must tile both tap rates (50, 10); 100 = quick
    return live_overhead.run(steps=100, repeats=3, out_path=out_path)


SUITES: Dict[str, Suite] = {
    "trace": Suite(
        baseline="BENCH_trace_overhead.json",
        fresh=_fresh_trace,
        checks=[
            Check("claim_holds", "bool"),
            Check("trace_overhead_frac", "ceiling", 0.05),
            Check("zeta_compute_frac", "abs", 0.25),
        ]),
    "safeguard": Suite(
        baseline="BENCH_safeguard_overhead.json",
        fresh=_fresh_safeguard,
        checks=[
            Check("flat_speedup_vs_stacked", "floor", 1.0,
                  extract=_speedup_entries),
        ]),
    "campaign": Suite(
        baseline="BENCH_campaign_throughput.json",
        fresh=_fresh_campaign,
        checks=[
            Check("max_acc_drift", "ceiling", 0.0),
            Check("vmap_speedup", "floor", 1.0),
        ]),
    "live": Suite(
        baseline="BENCH_live_overhead.json",
        fresh=_fresh_live,
        checks=[
            Check("claim_holds", "bool"),
            Check("taps_fired_ok", "bool"),
            Check("tap50_overhead_frac", "ceiling", 0.02),
            Check("tap10_overhead_frac", "ceiling", 0.10),
        ]),
}


def compare(base: Dict, fresh: Dict, checks: List[Check],
            name: str = "") -> List[str]:
    """Run every check of one suite; returns failure messages (empty =
    pass) and prints one CSV verdict line per compared value."""
    failures: List[str] = []
    for c in checks:
        b_vals, f_vals = c.values(base), c.values(fresh)
        for label in sorted(set(b_vals) | set(f_vals)):
            tag = f"{c.metric}[{label}]" if label else c.metric
            if label not in b_vals or label not in f_vals:
                side = "baseline" if label not in b_vals else "fresh"
                print(f"regress,{name},{tag},skipped,missing in {side}")
                continue
            b, f = b_vals[label], f_vals[label]
            if c.kind == "bool":
                ok = bool(f) == bool(b)
                detail = f"base,{b},fresh,{f}"
            elif c.kind == "abs":
                ok = abs(f - b) <= c.tol
                detail = f"base,{b},fresh,{f},tol,{c.tol}"
            elif c.kind == "ceiling":
                ok = b <= c.tol and f <= c.tol
                detail = f"base,{b},fresh,{f},ceiling,{c.tol}"
            else:                                           # floor
                ok = b >= c.tol and f >= c.tol
                detail = f"base,{b},fresh,{f},floor,{c.tol}"
            verdict = "ok" if ok else "FAIL"
            print(f"regress,{name},{tag},{detail},{verdict}")
            if not ok:
                failures.append(f"{name}: {tag} ({detail})")
    return failures


def run(only: Optional[List[str]] = None, against: Optional[str] = None,
        baseline_dir: Path = REPO_ROOT,
        scratch: Optional[str] = None) -> List[str]:
    """Gate the selected suites; returns the list of failures."""
    import tempfile
    names = only or sorted(SUITES)
    unknown = [n for n in names if n not in SUITES]
    if unknown:
        raise SystemExit(f"regress: unknown suite(s) {unknown}; "
                         f"have {sorted(SUITES)}")
    failures: List[str] = []
    with tempfile.TemporaryDirectory() as tmp:
        out_dir = scratch or tmp
        for n in names:
            suite = SUITES[n]
            base_path = Path(baseline_dir) / suite.baseline
            if not base_path.is_file():
                failures.append(f"{n}: missing baseline {base_path}")
                print(f"regress,{n},baseline,missing,{base_path},FAIL")
                continue
            with open(base_path) as f:
                base = json.load(f)
            if against is not None:
                fresh_path = Path(against) / suite.baseline
                if not fresh_path.is_file():
                    failures.append(f"{n}: missing fresh record "
                                    f"{fresh_path}")
                    print(f"regress,{n},fresh,missing,{fresh_path},FAIL")
                    continue
                with open(fresh_path) as f:
                    fresh = json.load(f)
            else:
                fresh = suite.fresh(os.path.join(out_dir,
                                                 suite.baseline))
            failures.extend(compare(base, fresh, suite.checks, name=n))
    status = "FAIL" if failures else "ok"
    print(f"regress,suites,{len(names)},failures,{len(failures)},{status}")
    return failures


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.regress",
        description="diff fresh benchmark runs against the committed "
                    "BENCH_*.json baselines")
    ap.add_argument("--check", action="store_true",
                    help="re-run quick benchmarks and gate (CI mode)")
    ap.add_argument("--against", default=None, metavar="DIR",
                    help="diff pre-computed records in DIR instead of "
                         "re-running")
    ap.add_argument("--only", default=None,
                    help=f"comma-separated subset of {sorted(SUITES)}")
    ap.add_argument("--baseline-dir", default=str(REPO_ROOT))
    args = ap.parse_args(argv)
    if not args.check and args.against is None:
        ap.error("nothing to do: pass --check or --against DIR")
    only = args.only.split(",") if args.only else None
    failures = run(only=only, against=args.against,
                   baseline_dir=Path(args.baseline_dir))
    for msg in failures:
        print(f"regress: FAIL {msg}")
    return 1 if failures else 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
