"""Live-telemetry overhead: does ``tap_every=50`` cost <2% step time?

The flight recorder's layer-4 promise (DESIGN.md §17) is that streaming
one bounded heartbeat per K-step window out of a running scan — the
``scan_trial(tap_every=K)`` path, ``jax.experimental.io_callback`` into
``repro.obs.live.LiveCollector`` — is cheap enough to leave on for every
campaign.  Three scan-rolled variants, all with full trace capture (the
realistic flight-recorder-on configuration):

  * **untapped**   ``tap_every=0`` — the flat single scan; baseline;
  * **tapped_50**  one heartbeat per 50 steps — the <2% claim;
  * **tapped_10**  one heartbeat per 10 steps — 5x denser, reported for
                   context (how the cost scales with tap rate).

The tap target is a minimal host counter (not a full
``LiveCollector``) so the measured cost is the device<->host round trip
plus the nested-scan restructuring, not json/file I/O — the collector's
own host work happens off the measured path in real runs too (callbacks
are async-dispatched; ``block_until_ready`` on the result does not wait
on the host side's json writes).

All variants are AOT-compiled (``obs.profile.profile_compiled``) so the
nested scan's extra compile time is visible separately from execute
time.  The model is the benchmark protocol's teacher-student MLP at
d_hidden=256, matching ``benchmarks/trace_overhead.py``.

Writes ``BENCH_live_overhead.json`` (committed at the repo root).
"""

from __future__ import annotations

import json
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core import attacks as atk_lib
from repro.data import tasks
from repro.obs import profile as prof
from repro.optim import make_optimizer
from repro.train import init_train_state, make_train_step, scan_trial
from benchmarks import common


class _CountingTap:
    """Minimal callback target: counts beats, keeps the last payload."""

    def __init__(self):
        self.count = 0
        self.last = None

    def __call__(self, payload):
        self.count += 1
        self.last = payload


def _trial_fn(task, *, steps: int, tap_every: int, tap=None,
              lr: float = 0.05, batch: int = 100, seed: int = 0):
    """A self-contained scan-rolled trial closure (same program family
    as ``trace_overhead._trial_fn``: variance attack, safeguard_double,
    full capture)."""
    attack = atk_lib.make_registry(steps=steps)["variance"]
    defense = common.make_defense("safeguard_double")
    opt = make_optimizer(TrainConfig(lr=lr))

    def trial():
        params = tasks.student_init(task, seed=seed + 1)
        state = init_train_state(params, opt, defense=defense,
                                 attack=attack, seed=seed)
        step = make_train_step(tasks.mlp_loss, opt, byz_mask=common.BYZ,
                               defense=defense, attack=attack, jit=False)

        def batch_fn(t):
            key = jax.random.fold_in(jax.random.PRNGKey(seed), t)
            x = jax.random.normal(
                key, (common.M, batch // common.M, task.d_in),
                jnp.float32)
            y = jnp.argmax(tasks.mlp_apply(task.teacher, x), axis=-1)
            return {"x": x, "y": y}

        final, traces = scan_trial(step, state, batch_fn=batch_fn,
                                   steps=steps, tap_every=tap_every,
                                   tap=tap)
        return final.params["w1"].sum(), traces

    return trial


def run(steps: int = 150, repeats: int = 5,
        out_path: str = "BENCH_live_overhead.json") -> Dict:
    task = tasks.make_teacher_task(d_in=64, d_hidden=256, n_classes=10)

    taps = {"tapped_50": _CountingTap(), "tapped_10": _CountingTap()}
    variants = {
        "untapped": _trial_fn(task, steps=steps, tap_every=0),
        "tapped_50": _trial_fn(task, steps=steps, tap_every=50,
                               tap=taps["tapped_50"]),
        "tapped_10": _trial_fn(task, steps=steps, tap_every=10,
                               tap=taps["tapped_10"]),
    }
    rows = {}
    for name, fn in variants.items():
        rec = prof.profile_compiled(fn, repeats=repeats)
        rec.pop("_out")
        jax.effects_barrier()           # drain async callback dispatches
        row = {**rec, "us_per_step": round(1e6 * rec["execute_s"] / steps,
                                           3)}
        if name in taps:
            row["taps_fired"] = taps[name].count
        rows[name] = row
        print(f"live_overhead,{name},execute_s,{rec['execute_s']:.4f},"
              f"compile_s,{rec['compile_s']:.2f},"
              f"taps,{row.get('taps_fired', 0)}")

    base = rows["untapped"]["execute_s"]
    frac_50 = (rows["tapped_50"]["execute_s"] - base) / base
    frac_10 = (rows["tapped_10"]["execute_s"] - base) / base
    # every timed execution of a tapped program must have fired its
    # heartbeats, else the "overhead" measured nothing
    fired_ok = (rows["tapped_50"]["taps_fired"]
                >= (steps // 50) * rows["tapped_50"]["repeats"]
                and rows["tapped_10"]["taps_fired"]
                >= (steps // 10) * rows["tapped_10"]["repeats"])
    result = {
        "task": {"d_in": task.d_in, "d_hidden": 256, "n_classes": 10,
                 "m": common.M, "n_byz": common.N_BYZ, "steps": steps},
        "repeats": repeats,
        "variants": rows,
        "tap50_overhead_frac": round(frac_50, 4),
        "tap10_overhead_frac": round(frac_10, 4),
        "taps_fired_ok": bool(fired_ok),
        "claim": "live tapping at tap_every=50 (one io_callback "
                 "heartbeat per window, nested-scan restructuring "
                 "included) costs <2% of the untapped execute time",
        "claim_holds": bool(frac_50 < 0.02 and fired_ok),
    }
    print(f"live_overhead,tap50_frac,{frac_50:.4f},"
          f"tap10_frac,{frac_10:.4f},"
          f"claim_holds,{result['claim_holds']}")
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
        f.write("\n")
    return result


if __name__ == "__main__":
    run()
