"""Benchmark driver — one module per paper table/figure plus the roofline
report.  ``python -m benchmarks.run [--quick]`` prints one CSV line per
measurement (``name,...``) and writes JSON artifacts under
``experiments/bench/``.

  table1          Paper Table 1: attack x defense accuracy grid
  fig2a           Paper Fig 2(a): detection-statistic growth exponents
  fig2b           Paper Fig 2(b): periodic good-set reset (transients)
  convex_attack   Appendix C.3: burst attack vs unwindowed filter
  saddle_escape   escape-time distributions on the planted-saddle
                  testbed vs the theorem's predicted budget
  overhead        master aggregation O(md) cost per defense
  campaign        campaign engine throughput: per-loop Trainer trials vs
                  the scan+vmap engine (BENCH_campaign_throughput.json)
  trace_overhead  flight-recorder cost: full-schema trace capture vs
                  trace_zeta=False (BENCH_trace_overhead.json)
  live_overhead   live-telemetry cost: scan_trial tap_every=50/10 vs
                  untapped (BENCH_live_overhead.json)
  kernels         Pallas kernels (interpret) vs jnp reference
  roofline        three-term roofline per (arch x shape) from the dry runs
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer steps per experiment (~3x faster)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmarks")
    args = ap.parse_args()
    steps = 60 if args.quick else 150

    from benchmarks import (table1_attack_grid, fig2_detection, fig2_reset,
                            convex_attack, saddle_escape, overhead,
                            campaign_throughput, bench_kernels, roofline,
                            trace_overhead, live_overhead)
    jobs = {
        "table1": lambda: table1_attack_grid.run(steps=steps),
        "fig2a": lambda: fig2_detection.run(steps=max(steps, 120)),
        "fig2b": lambda: fig2_reset.run(steps=steps),
        "convex_attack": lambda: convex_attack.run(steps=max(steps, 150)),
        "saddle_escape": lambda: saddle_escape.run(
            steps=300 if args.quick else 400,
            seeds=2 if args.quick else 3),
        "overhead": lambda: overhead.run(quick=args.quick),
        "campaign": lambda: campaign_throughput.run(quick=args.quick),
        "trace_overhead": lambda: trace_overhead.run(
            steps=60 if args.quick else 150),
        "live_overhead": lambda: live_overhead.run(
            steps=100 if args.quick else 150),
        "kernels": bench_kernels.run,
        "roofline": roofline.run,
    }
    selected = (args.only.split(",") if args.only else list(jobs))
    for name in selected:
        t0 = time.time()
        print(f"\n===== {name} =====")
        try:
            jobs[name]()
        except Exception as e:                          # noqa: BLE001
            import traceback
            traceback.print_exc()
            print(f"{name},FAILED,{e}")
            sys.exit(1)
        print(f"{name},wall_s,{time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
