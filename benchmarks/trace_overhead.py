"""Flight-recorder overhead: does full-schema trace capture cost <5%?

The obs layer's promise (ISSUE 7 / DESIGN.md §15) is that *recording*
the decision timeline — per-worker distances, thresholds, the good
mask — is cheap enough to leave on.  Recording is distinct from
computing: ``trace_zeta`` is a compute knob (two extra O(m d) passes
over the gradients per step), not a capture knob, so the capture claim
is measured at ``trace_zeta=False`` on both sides and the zeta-pass
cost is reported separately.  Three scan-rolled variants:

  * **no_capture**        ``trace_zeta=False``, ``trace_fields=()`` —
                          the scan carries no ys at all (zero trace
                          memory); the baseline;
  * **full_capture**      ``trace_zeta=False``, every metric the step
                          emits stacked over the step axis — the <5%
                          claim is full_capture vs no_capture;
  * **full_capture_zeta** ``trace_zeta=True`` + full capture — the
                          everything-on configuration, reported so the
                          zeta compute cost is visible, not hidden.

All variants are AOT-compiled (``obs.profile.profile_compiled``) so
compile time is reported separately from execute time, with loop-aware
FLOPs/HBM attribution from ``launch.hlo_analysis``.  The model is the
benchmark protocol's teacher-student MLP at d_hidden=256 — large enough
that the gradient computation, not the trace plumbing, dominates the
step (at toy sizes the ~steps×m trace writes would be measuring numpy,
not the recorder).

Writes ``BENCH_trace_overhead.json`` (committed at the repo root).
"""

from __future__ import annotations

import json
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core import attacks as atk_lib
from repro.data import tasks
from repro.obs import profile as prof
from repro.optim import make_optimizer
from repro.train import init_train_state, make_train_step, scan_trial
from benchmarks import common


def _trial_fn(task, *, steps: int, trace_zeta: bool, traced: bool,
              lr: float = 0.05, batch: int = 100, seed: int = 0):
    """A self-contained scan-rolled trial closure (no knob axes — this
    benchmark compares program variants, not scenarios)."""
    attack = atk_lib.make_registry(steps=steps)["variance"]
    defense = common.make_defense("safeguard_double")
    opt = make_optimizer(TrainConfig(lr=lr))

    def trial():
        params = tasks.student_init(task, seed=seed + 1)
        state = init_train_state(params, opt, defense=defense,
                                 attack=attack, seed=seed)
        step = make_train_step(tasks.mlp_loss, opt, byz_mask=common.BYZ,
                               defense=defense, attack=attack,
                               trace_zeta=trace_zeta, jit=False)

        def batch_fn(t):
            key = jax.random.fold_in(jax.random.PRNGKey(seed), t)
            x = jax.random.normal(
                key, (common.M, batch // common.M, task.d_in),
                jnp.float32)
            y = jnp.argmax(tasks.mlp_apply(task.teacher, x), axis=-1)
            return {"x": x, "y": y}

        final, traces = scan_trial(step, state, batch_fn=batch_fn,
                                   steps=steps,
                                   trace_fields=None if traced else ())
        return final.params["w1"].sum(), traces

    return trial


def run(steps: int = 150, repeats: int = 5,
        out_path: str = "BENCH_trace_overhead.json") -> Dict:
    task = tasks.make_teacher_task(d_in=64, d_hidden=256, n_classes=10)

    variants = {
        "no_capture": _trial_fn(task, steps=steps,
                                trace_zeta=False, traced=False),
        "full_capture": _trial_fn(task, steps=steps,
                                  trace_zeta=False, traced=True),
        "full_capture_zeta": _trial_fn(task, steps=steps,
                                       trace_zeta=True, traced=True),
    }
    rows = {}
    for name, fn in variants.items():
        rec = prof.profile_compiled(fn, repeats=repeats)
        out = rec.pop("_out")
        n_fields = len(out[1]) if isinstance(out[1], dict) else 0
        rows[name] = {**rec, "traced_fields": n_fields,
                      "us_per_step": round(1e6 * rec["execute_s"] / steps,
                                           3)}
        print(f"trace_overhead,{name},execute_s,{rec['execute_s']:.4f},"
              f"compile_s,{rec['compile_s']:.2f},fields,{n_fields}")

    base = rows["no_capture"]["execute_s"]
    full = rows["full_capture"]["execute_s"]
    overhead = (full - base) / base
    zeta_cost = (rows["full_capture_zeta"]["execute_s"] - full) / base
    result = {
        "task": {"d_in": task.d_in, "d_hidden": 256, "n_classes": 10,
                 "m": common.M, "n_byz": common.N_BYZ, "steps": steps},
        "repeats": repeats,
        "variants": rows,
        "trace_overhead_frac": round(overhead, 4),
        "zeta_compute_frac": round(zeta_cost, 4),
        "claim": "full-schema trace capture within 5% of the "
                 "trace_zeta=False baseline (capture cost; the zeta "
                 "O(m d) compute passes are reported separately)",
        "claim_holds": bool(overhead < 0.05),
    }
    print(f"trace_overhead,capture_frac,{overhead:.4f},"
          f"zeta_compute_frac,{zeta_cost:.4f},"
          f"claim_holds,{result['claim_holds']}")
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
        f.write("\n")
    return result


if __name__ == "__main__":
    run()
