"""Paper Figure 2(a): the detection statistic ||B_i - B_med|| grows
~sqrt(t) for honest workers but ~linearly for a variance attacker.  We fit
the growth exponent of both and report the ratio.

Two cells, both straight off the campaign engine:

* **growth cell** — eviction disabled by a huge threshold floor so the
  statistic stays observable all run; the per-step, per-worker
  ``dist_to_med_B`` comes from the engine's traces (DESIGN.md §13) and
  the exponents are fit on it, as before.
* **detection cell** — eviction *enabled*; instead of re-deriving
  eviction steps from raw trace arrays, this is the first consumer of
  the flight recorder's event layer (DESIGN.md §15): the engine record
  already carries the extracted event log, and ``obs.events.summarize``
  reports each colluder's eviction step, triggering guard, and the
  distance/threshold pair that fired — cross-checked against the
  trainer's own ``caught_byz`` trace via ``obs.events.caught_curve``.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.campaign import engine
from repro.campaign.scenario import Scenario, scenario_id
from repro.obs import events as ev_lib
from benchmarks import common


def run(steps: int = 200, out_dir: str = "experiments/bench"):
    growth = Scenario(attack="variance", defense="safeguard_double",
                      steps=steps, lr=0.05, m=common.M, n_byz=common.N_BYZ,
                      # disable eviction (huge windows + floor) so the
                      # statistic is observable all run
                      T0=10 ** 6, T1=10 ** 6, threshold_floor=10 ** 6)
    detect = Scenario(attack="variance", defense="safeguard_double",
                      steps=steps, lr=0.05, m=common.M, n_byz=common.N_BYZ)
    res = engine.run_scenarios([growth, detect])

    # -- growth exponents (eviction-disabled cell) -------------------------
    rec = res[scenario_id(growth)]
    dist = np.asarray(rec["traces"]["dist_to_med_B"])      # (steps, m)
    arr = np.stack([dist[:, :common.N_BYZ].mean(axis=1),
                    dist[:, common.N_BYZ:].mean(axis=1)], axis=1)
    ts = np.arange(10, steps)
    fit = {}
    for j, name in enumerate(("byz", "honest")):
        y = np.log(np.maximum(arr[10:, j], 1e-9))
        x = np.log(ts)
        slope = np.polyfit(x, y, 1)[0]
        fit[name] = float(slope)
        print(f"fig2a,{name}_growth_exponent,{slope:.3f}")
    print(f"fig2a,exponent_ratio,{fit['byz'] / max(fit['honest'], 1e-9):.2f}")

    # -- detection forensics (eviction-enabled cell, event layer) ----------
    drec = res[scenario_id(detect)]
    events = ev_lib.events_from_json(drec["events"])
    summ = ev_lib.summarize(events, n_byz=common.N_BYZ, m=common.M)
    for k, c in summ["caught"].items():
        print(f"fig2a,evicted,worker={k},step={c['step']},"
              f"guard={c['guard']},dist={c['dist']:.4g},"
              f"threshold={c['threshold']:.4g}")
    print(f"fig2a,detection_latency,"
          f"{summ['detection_latency_first']}..{summ['detection_latency_last']}")
    print(f"fig2a,false_evictions,{summ['n_false_evictions']}")
    # the event replay must agree with the trainer's own timeline
    curve = ev_lib.caught_curve(events, common.N_BYZ, common.M, steps)
    trainer_curve = np.asarray(drec["traces"]["caught_byz"])
    assert np.array_equal(curve, trainer_curve), \
        "event-layer caught curve diverges from the trainer's caught_byz"

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig2a.json"), "w") as f:
        json.dump({"trajectory": arr.tolist(), "exponents": fit,
                   "detection": summ}, f)
    return {"exponents": fit, "detection": summ}


if __name__ == "__main__":
    run()
