"""Paper Figure 2(a): the detection statistic ||B_i - B_med|| grows
~sqrt(t) for honest workers but ~linearly for a variance attacker.  We fit
the growth exponent of both and report the ratio.

The per-step, per-worker statistic comes straight out of the campaign
engine's traces (``dist_to_med_B``, published by the safeguard through
the Defense info and traced by the trainer — DESIGN.md §13's trace
layer): one scan-rolled trial, no hand-rolled training loop.  Eviction
is disabled by a huge threshold floor so the statistic stays observable
for the whole run.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.campaign import engine
from repro.campaign.scenario import Scenario, scenario_id
from benchmarks import common


def run(steps: int = 200, out_dir: str = "experiments/bench"):
    scn = Scenario(attack="variance", defense="safeguard_double",
                   steps=steps, lr=0.05, m=common.M, n_byz=common.N_BYZ,
                   # disable eviction (huge windows + floor) so the
                   # statistic is observable all run
                   T0=10 ** 6, T1=10 ** 6, threshold_floor=10 ** 6)
    rec = engine.run_scenarios([scn])[scenario_id(scn)]
    dist = np.asarray(rec["traces"]["dist_to_med_B"])      # (steps, m)
    arr = np.stack([dist[:, :common.N_BYZ].mean(axis=1),
                    dist[:, common.N_BYZ:].mean(axis=1)], axis=1)

    ts = np.arange(10, steps)
    fit = {}
    for j, name in enumerate(("byz", "honest")):
        y = np.log(np.maximum(arr[10:, j], 1e-9))
        x = np.log(ts)
        slope = np.polyfit(x, y, 1)[0]
        fit[name] = float(slope)
        print(f"fig2a,{name}_growth_exponent,{slope:.3f}")
    print(f"fig2a,exponent_ratio,{fit['byz'] / max(fit['honest'], 1e-9):.2f}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig2a.json"), "w") as f:
        json.dump({"trajectory": arr.tolist(), "exponents": fit}, f)
    return fit


if __name__ == "__main__":
    run()
