"""Paper Figure 2(a): the detection statistic ||B_i - B_med|| grows
~sqrt(t) for honest workers but ~linearly for a variance attacker.  We fit
the growth exponent of both and report the ratio."""

from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from repro.data import tasks
from benchmarks import common


def run(steps: int = 200, out_dir: str = "experiments/bench"):
    task = tasks.make_teacher_task()
    traj = {"byz": [], "honest": []}

    def collect(i, state, metrics):
        pass

    # disable eviction (huge floor) so the statistic is observable all run
    from repro.core import SafeguardConfig, init_state, safeguard_step
    from repro.core import attacks as atk_lib
    from repro.configs.base import TrainConfig
    from repro.optim import make_optimizer
    from repro.train import init_train_state, make_train_step
    import jax

    sg_cfg = SafeguardConfig(m=common.M, T0=10 ** 6, T1=10 ** 6,
                             threshold_floor=10 ** 6)
    attack = atk_lib.make_variance_attack(z_max=1.5)
    opt = make_optimizer(TrainConfig(lr=0.05))
    params = tasks.student_init(task)
    state = init_train_state(params, opt, sg_cfg=sg_cfg)
    loss = tasks.mlp_loss
    step = make_train_step(
        loss, opt, byz_mask=common.BYZ, sg_cfg=sg_cfg,
        attack=atk_lib.Attack("variance", attack))
    it = tasks.teacher_batches(task, 100, m=common.M)
    import repro.core.safeguard as sg
    # re-run manually to capture info
    st = state
    stats = []
    for t in range(steps):
        b = next(it)
        # one manual step to capture dist_to_med
        vg = jax.value_and_grad(loss)
        _, grads = jax.vmap(lambda wb: vg(st.params, wb))(b)
        grads, astate = attack(grads, common.BYZ, st.attack_state,
                               st.step, jax.random.PRNGKey(t))
        sg_state, agg, info = sg.safeguard_step(st.sg_state, grads, sg_cfg)
        new_params, opt_state = opt.update(agg, st.opt_state, st.params,
                                           st.step)
        from repro.train.trainer import TrainState
        st = TrainState(params=new_params, opt_state=opt_state,
                        defense_state=sg_state, attack_state=astate,
                        step=st.step + 1, rng=st.rng)
        d = np.asarray(info["dist_to_med_B"])
        stats.append((float(d[:common.N_BYZ].mean()),
                      float(d[common.N_BYZ:].mean())))

    arr = np.array(stats)  # (steps, 2): byz, honest
    ts = np.arange(10, steps)
    fit = {}
    for j, name in enumerate(("byz", "honest")):
        y = np.log(np.maximum(arr[10:, j], 1e-9))
        x = np.log(ts)
        slope = np.polyfit(x, y, 1)[0]
        fit[name] = float(slope)
        print(f"fig2a,{name}_growth_exponent,{slope:.3f}")
    print(f"fig2a,exponent_ratio,{fit['byz'] / max(fit['honest'], 1e-9):.2f}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig2a.json"), "w") as f:
        json.dump({"trajectory": arr.tolist(), "exponents": fit}, f)
    return fit


if __name__ == "__main__":
    run()
