"""Shared benchmark scaffolding: the paper's experimental protocol
(Section 5 / Appendix C) at CPU scale — m = 10 workers, alpha = 0.4,
teacher-student task replacing ResNet-20/CIFAR (offline substitute,
DESIGN.md §9).

Single-cell experiments route through the campaign engine
(``repro.campaign.engine``, DESIGN.md §10): the whole trial is one
``lax.scan`` program instead of ~150 python-dispatched steps.
``run_experiment_loop`` keeps the legacy per-step ``Trainer`` path — it
is the numerics oracle the engine is tested against (bit-identical
trajectories, ``tests/test_campaign.py``) and the per-loop baseline of
``benchmarks/campaign_throughput.py``; it is also used whenever a
``collect`` callback needs to observe python-side state every step.
"""

from __future__ import annotations

import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.campaign import engine as campaign_engine
from repro.campaign.engine import EVAL_BATCH, EVAL_KEY
from repro.campaign.scenario import (Scenario, TABLE1_ATTACKS,
                                     TABLE1_DEFENSES, scenario_id)
from repro.configs.base import TrainConfig
from repro.core import aggregators as agg_lib
from repro.core import attacks as atk_lib
from repro.core import defenses as dfn_lib
from repro.data import hetero as het_lib
from repro.data import tasks
from repro.optim import make_optimizer
from repro.train import Trainer, init_train_state, make_train_step

M, N_BYZ = 10, 4
BYZ = jnp.arange(M) < N_BYZ

# canonical Table-1 grid lives in repro.campaign.scenario
ATTACKS = list(TABLE1_ATTACKS)
DEFENSES = list(TABLE1_DEFENSES)


def make_defense(name: str, *, t0=20, t1=120, floor=0.1, reset_period=0,
                 scale=dfn_lib.DEFENSE_DEFAULTS["threshold_scale"],
                 bucket_s: int = dfn_lib.DEFENSE_DEFAULTS["bucket_s"]
                 ) -> dfn_lib.Defense:
    """The benchmark protocol's defense instances (unified registry,
    DESIGN.md §12)."""
    return dfn_lib.make_registry(M, N_BYZ, T0=t0, T1=t1,
                                 threshold_floor=floor,
                                 threshold_scale=scale,
                                 reset_period=reset_period,
                                 bucket_s=bucket_s)[name]


def scenario_for(attack_name: str, defense_name: str, *, steps: int = 150,
                 lr: float = 0.1, batch: int = 100, seed: int = 0,
                 reset_period: int = 0, hetero: str = "iid",
                 hetero_alpha: float = 0.0, hetero_shift: float = 0.0,
                 bucket_s: int = dfn_lib.DEFENSE_DEFAULTS["bucket_s"],
                 task: Optional[tasks.TeacherTask] = None) -> Scenario:
    """The campaign-engine Scenario equivalent of ``run_experiment``'s
    arguments (same task shape, windows, thresholds, rng scheme)."""
    kw = {}
    if task is not None:
        kw = dict(d_in=task.d_in, d_hidden=task.d_hidden,
                  n_classes=task.n_classes, task_seed=task.seed)
    return Scenario(attack=attack_name, defense=defense_name, m=M,
                    n_byz=N_BYZ, steps=steps, seed=seed, lr=lr, batch=batch,
                    reset_period=reset_period, hetero=hetero,
                    hetero_alpha=hetero_alpha, hetero_shift=hetero_shift,
                    bucket_s=bucket_s, **kw)


def run_experiment(task, attack_name: str, defense_name: str, *,
                   steps: int = 150, lr: float = 0.1, batch: int = 100,
                   seed: int = 0, reset_period: int = 0, hetero: str = "iid",
                   hetero_alpha: float = 0.0, hetero_shift: float = 0.0,
                   collect=None) -> Dict:
    """One grid cell.  Engine path (scan-rolled trial) unless a
    ``collect`` callback needs per-step python visibility."""
    if collect is not None:
        return run_experiment_loop(task, attack_name, defense_name,
                                   steps=steps, lr=lr, batch=batch,
                                   seed=seed, reset_period=reset_period,
                                   hetero=hetero, hetero_alpha=hetero_alpha,
                                   hetero_shift=hetero_shift,
                                   collect=collect)
    scn = scenario_for(attack_name, defense_name, steps=steps, lr=lr,
                       batch=batch, seed=seed, reset_period=reset_period,
                       hetero=hetero, hetero_alpha=hetero_alpha,
                       hetero_shift=hetero_shift, task=task)
    t0_wall = time.time()
    rec = campaign_engine.run_scenarios([scn])[scenario_id(scn)]
    out = {"attack": attack_name, "defense": defense_name,
           "acc": rec["acc"], "steps": steps,
           "wall_s": round(time.time() - t0_wall, 2)}
    for k in ("caught_byz", "evicted_honest"):
        if k in rec:
            out[k] = rec[k]
    return out


def run_experiment_loop(task, attack_name: str, defense_name: str, *,
                        steps: int = 150, lr: float = 0.1, batch: int = 100,
                        seed: int = 0, reset_period: int = 0,
                        hetero: str = "iid", hetero_alpha: float = 0.0,
                        hetero_shift: float = 0.0,
                        collect=None) -> Dict:
    """Legacy per-trial ``Trainer`` path: one jit, python-loop steps."""
    # steps is forwarded so the burst window derives from the trial length
    # (and an unfireable explicit window fails loudly) — same derivation
    # as the engine path, keeping the two bit-identical
    attack = atk_lib.make_registry(delay=32, steps=steps)[attack_name]
    defense = make_defense(defense_name, reset_period=reset_period)
    opt = make_optimizer(TrainConfig(lr=lr))
    params = tasks.student_init(task, seed=seed + 1)
    state = init_train_state(params, opt, defense=defense, attack=attack,
                             seed=seed)
    step = make_train_step(tasks.mlp_loss, opt, byz_mask=BYZ,
                           defense=defense, attack=attack)
    flip = BYZ if attack.data_attack else None
    if hetero != "iid":
        # the hetero iterator shares the engine batch_fn's key schedule
        # and selection (repro.data.hetero) — bit-identical paths
        it = het_lib.hetero_batches(task, batch, mode=hetero,
                                    alpha=hetero_alpha, shift=hetero_shift,
                                    seed=seed, m=M, flip_mask=flip)
    else:
        it = tasks.teacher_batches(task, batch, seed=seed, m=M,
                                   flip_mask=flip)
    held = (tasks.teacher_batches(task, 10, seed=seed + 7)
            if defense.needs_held_batch else None)
    tr = Trainer(state, step, it, held_iter=held, log_every=10 ** 9,
                 name=f"{attack_name}/{defense_name}")
    t0_wall = time.time()
    if collect is None:
        tr.run(steps, verbose=False)
    else:
        for i in range(steps):
            b = next(tr.data_iter)
            if held is not None:
                tr.state, metrics = tr.step_fn(tr.state, b, next(held))
            else:
                tr.state, metrics = tr.step_fn(tr.state, b)
            collect(i, tr.state, metrics)
    wall = time.time() - t0_wall
    eval_b = tasks.teacher_batch(task, jax.random.PRNGKey(EVAL_KEY),
                                 EVAL_BATCH)
    acc = float(tasks.mlp_accuracy(tr.state.params, eval_b))
    out = {"attack": attack_name, "defense": defense_name, "acc": acc,
           "steps": steps, "wall_s": round(wall, 2)}
    good = dfn_lib.final_good(tr.state.defense_state)
    if good is not None:
        out["caught_byz"] = int((BYZ & ~good).sum())
        out["evicted_honest"] = int((~BYZ & ~good).sum())
    return out


def ideal_accuracy(task, *, steps=150, lr=0.1, batch=60, seed=0) -> float:
    """SGD on honest workers only — the paper's 'ideal accuracy'."""
    opt = make_optimizer(TrainConfig(lr=lr))
    params = tasks.student_init(task, seed=seed + 1)
    agg = agg_lib.Aggregator("mean", agg_lib.mean)
    mh = M - N_BYZ
    state = init_train_state(params, opt)
    step = make_train_step(tasks.mlp_loss, opt,
                           byz_mask=jnp.zeros((mh,), bool),
                           aggregator=agg)
    it = tasks.teacher_batches(task, batch, seed=seed, m=mh)
    tr = Trainer(state, step, it, log_every=10 ** 9, name="ideal")
    tr.run(steps, verbose=False)
    eval_b = tasks.teacher_batch(task, jax.random.PRNGKey(EVAL_KEY),
                                 EVAL_BATCH)
    return float(tasks.mlp_accuracy(tr.state.params, eval_b))
