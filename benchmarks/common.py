"""Shared benchmark scaffolding: the paper's experimental protocol
(Section 5 / Appendix C) at CPU scale — m = 10 workers, alpha = 0.4,
teacher-student task replacing ResNet-20/CIFAR (offline substitute,
DESIGN.md §9).

Single-cell experiments route through the campaign engine
(``repro.campaign.engine``, DESIGN.md §10): the whole trial is one
``lax.scan`` program instead of ~150 python-dispatched steps.
``run_experiment_loop`` keeps the legacy per-step ``Trainer`` path — it
is the numerics oracle the engine is tested against (bit-identical
trajectories, ``tests/test_campaign.py``) and the per-loop baseline of
``benchmarks/campaign_throughput.py``; it is also used whenever a
``collect`` callback needs to observe python-side state every step.
"""

from __future__ import annotations

import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.campaign import engine as campaign_engine
from repro.campaign.engine import EVAL_BATCH, EVAL_KEY
from repro.campaign.scenario import (Scenario, TABLE1_ATTACKS,
                                     TABLE1_DEFENSES, scenario_id)
from repro.configs.base import TrainConfig
from repro.core import aggregators as agg_lib
from repro.core import attacks as atk_lib
from repro.core import defenses as dfn_lib
from repro.data import hetero as het_lib
from repro.data import saddle as sad_lib
from repro.data import tasks
from repro.optim import make_optimizer
from repro.train import Trainer, init_train_state, make_train_step

M, N_BYZ = 10, 4
BYZ = jnp.arange(M) < N_BYZ

# canonical Table-1 grid lives in repro.campaign.scenario
ATTACKS = list(TABLE1_ATTACKS)
DEFENSES = list(TABLE1_DEFENSES)


def make_defense(name: str, *, t0=20, t1=120, floor=0.1, reset_period=0,
                 scale=dfn_lib.DEFENSE_DEFAULTS["threshold_scale"],
                 bucket_s: int = dfn_lib.DEFENSE_DEFAULTS["bucket_s"]
                 ) -> dfn_lib.Defense:
    """The benchmark protocol's defense instances (unified registry,
    DESIGN.md §12)."""
    return dfn_lib.make_registry(M, N_BYZ, T0=t0, T1=t1,
                                 threshold_floor=floor,
                                 threshold_scale=scale,
                                 reset_period=reset_period,
                                 bucket_s=bucket_s)[name]


def scenario_for(attack_name: str, defense_name: str, *, steps: int = 150,
                 lr: float = 0.1, batch: int = 100, seed: int = 0,
                 reset_period: int = 0, hetero: str = "iid",
                 hetero_alpha: float = 0.0, hetero_shift: float = 0.0,
                 bucket_s: int = dfn_lib.DEFENSE_DEFAULTS["bucket_s"],
                 task: Optional[tasks.TeacherTask] = None) -> Scenario:
    """The campaign-engine Scenario equivalent of ``run_experiment``'s
    arguments (same task shape, windows, thresholds, rng scheme)."""
    kw = {}
    if task is not None:
        kw = dict(d_in=task.d_in, d_hidden=task.d_hidden,
                  n_classes=task.n_classes, task_seed=task.seed)
    return Scenario(attack=attack_name, defense=defense_name, m=M,
                    n_byz=N_BYZ, steps=steps, seed=seed, lr=lr, batch=batch,
                    reset_period=reset_period, hetero=hetero,
                    hetero_alpha=hetero_alpha, hetero_shift=hetero_shift,
                    bucket_s=bucket_s, **kw)


def run_experiment(task, attack_name: str, defense_name: str, *,
                   steps: int = 150, lr: float = 0.1, batch: int = 100,
                   seed: int = 0, reset_period: int = 0, hetero: str = "iid",
                   hetero_alpha: float = 0.0, hetero_shift: float = 0.0,
                   collect=None) -> Dict:
    """One grid cell.  Engine path (scan-rolled trial) unless a
    ``collect`` callback needs per-step python visibility."""
    if collect is not None:
        return run_experiment_loop(task, attack_name, defense_name,
                                   steps=steps, lr=lr, batch=batch,
                                   seed=seed, reset_period=reset_period,
                                   hetero=hetero, hetero_alpha=hetero_alpha,
                                   hetero_shift=hetero_shift,
                                   collect=collect)
    scn = scenario_for(attack_name, defense_name, steps=steps, lr=lr,
                       batch=batch, seed=seed, reset_period=reset_period,
                       hetero=hetero, hetero_alpha=hetero_alpha,
                       hetero_shift=hetero_shift, task=task)
    t0_wall = time.time()
    rec = campaign_engine.run_scenarios([scn])[scenario_id(scn)]
    out = {"attack": attack_name, "defense": defense_name,
           "acc": rec["acc"], "steps": steps,
           "wall_s": round(time.time() - t0_wall, 2)}
    for k in ("caught_byz", "evicted_honest"):
        if k in rec:
            out[k] = rec[k]
    return out


def run_experiment_loop(task, attack_name: str, defense_name: str, *,
                        steps: int = 150, lr: float = 0.1, batch: int = 100,
                        seed: int = 0, reset_period: int = 0,
                        hetero: str = "iid", hetero_alpha: float = 0.0,
                        hetero_shift: float = 0.0, t0: int = 20,
                        t1: int = 120, floor: float = 0.1,
                        burst_start: Optional[int] = None,
                        burst_length: int = 50,
                        collect=None) -> Dict:
    """Legacy per-trial ``Trainer`` path: one jit, python-loop steps."""
    # steps is forwarded so the burst window derives from the trial length
    # (and an unfireable explicit window fails loudly) — same derivation
    # as the engine path, keeping the two bit-identical
    attack = atk_lib.make_registry(delay=32, burst_start=burst_start,
                                   burst_length=burst_length,
                                   steps=steps)[attack_name]
    defense = make_defense(defense_name, t0=t0, t1=t1, floor=floor,
                           reset_period=reset_period)
    opt = make_optimizer(TrainConfig(lr=lr))
    params = tasks.student_init(task, seed=seed + 1)
    state = init_train_state(params, opt, defense=defense, attack=attack,
                             seed=seed)
    step = make_train_step(tasks.mlp_loss, opt, byz_mask=BYZ,
                           defense=defense, attack=attack)
    flip = BYZ if attack.data_attack else None
    if hetero != "iid":
        # the hetero iterator shares the engine batch_fn's key schedule
        # and selection (repro.data.hetero) — bit-identical paths
        it = het_lib.hetero_batches(task, batch, mode=hetero,
                                    alpha=hetero_alpha, shift=hetero_shift,
                                    seed=seed, m=M, flip_mask=flip)
    else:
        it = tasks.teacher_batches(task, batch, seed=seed, m=M,
                                   flip_mask=flip)
    held = (tasks.teacher_batches(task, 10, seed=seed + 7)
            if defense.needs_held_batch else None)
    tr = Trainer(state, step, it, held_iter=held, log_every=10 ** 9,
                 name=f"{attack_name}/{defense_name}")
    t0_wall = time.time()
    if collect is None:
        tr.run(steps, verbose=False)
    else:
        for i in range(steps):
            b = next(tr.data_iter)
            if held is not None:
                tr.state, metrics = tr.step_fn(tr.state, b, next(held))
            else:
                tr.state, metrics = tr.step_fn(tr.state, b)
            collect(i, tr.state, metrics)
    wall = time.time() - t0_wall
    eval_b = tasks.teacher_batch(task, jax.random.PRNGKey(EVAL_KEY),
                                 EVAL_BATCH)
    acc = float(tasks.mlp_accuracy(tr.state.params, eval_b))
    out = {"attack": attack_name, "defense": defense_name, "acc": acc,
           "steps": steps, "wall_s": round(wall, 2)}
    good = dfn_lib.final_good(tr.state.defense_state)
    if good is not None:
        out["caught_byz"] = int((BYZ & ~good).sum())
        out["evicted_honest"] = int((~BYZ & ~good).sum())
    return out


def saddle_scenario_for(kind: str, *, steps: int = 120, lr: float = 0.1,
                        batch: int = 40, seed: int = 0, d: int = 16,
                        gap: float = 1.0, noise_r: float = 0.05,
                        vr_period: int = 0,
                        defense_name: str = "safeguard_double",
                        attack_name: str = "none", perturb: str = "none",
                        escape_nu: float = 0.1,
                        escape_thresh: float = 0.1,
                        adapt_init: float =
                        atk_lib.ADAPTIVE_DEFAULTS["adapt_init"]
                        ) -> Scenario:
    """The campaign-engine Scenario equivalent of ``run_saddle_loop``'s
    arguments (same task, knobs, windows, rng scheme)."""
    return Scenario(task=kind, d_in=d, attack=attack_name,
                    defense=defense_name, m=M, n_byz=N_BYZ, steps=steps,
                    seed=seed, lr=lr, batch=batch, saddle_gap=gap,
                    noise_r=noise_r, vr_period=vr_period, perturb=perturb,
                    escape_nu=escape_nu, escape_thresh=escape_thresh,
                    adapt_init=adapt_init)


def run_saddle_loop(kind: str, *, steps: int = 120, lr: float = 0.1,
                    batch: int = 40, seed: int = 0, d: int = 16,
                    gap: float = 1.0, noise_r: float = 0.05,
                    vr_period: int = 0,
                    defense_name: str = "safeguard_double",
                    attack_name: str = "none", perturb: str = "none",
                    escape_nu: float = 0.1, escape_thresh: float = 0.1,
                    adapt_init: float =
                    atk_lib.ADAPTIVE_DEFAULTS["adapt_init"]) -> Dict:
    """Legacy per-step ``Trainer``-style path of the planted-saddle
    testbed (DESIGN.md §14) — the numerics oracle the engine's saddle
    lane is tested against: same rng streams, same op order, so the
    trajectories (including the second-order trace lane and the
    ``saddle_push`` attack state) are bit-identical.  Returns the full
    per-step metric traces alongside the scalar summary."""
    stask = sad_lib.make_saddle_task(d, kind)
    if attack_name == "saddle_push":
        attack = atk_lib.make_saddle_push(stask.dirs,
                                          boost_init=adapt_init)
    else:
        attack = atk_lib.make_registry(delay=32, steps=steps)[attack_name]
    defense = make_defense(defense_name)
    opt = make_optimizer(TrainConfig(lr=lr))
    loss_fn = sad_lib.make_saddle_loss(stask, gap, noise_r)
    state = init_train_state(sad_lib.x_init(stask), opt, defense=defense,
                             attack=attack, seed=seed)
    step = make_train_step(loss_fn, opt, byz_mask=BYZ, defense=defense,
                           attack=attack, perturb=perturb,
                           escape_nu=escape_nu,
                           escape_thresh=escape_thresh,
                           so_probe=sad_lib.make_probe(stask, gap))
    it = sad_lib.saddle_batches(stask, batch, seed=seed, m=M,
                                vr_period=vr_period)

    held = None
    if defense.needs_held_batch:
        def _held():
            t = 0
            while True:
                key = jax.random.fold_in(
                    jax.random.PRNGKey((seed + 7) ^ 0xDA7A), t)
                yield {"eps": jax.random.normal(key, (10, d), jnp.float32)}
                t += 1
        held = _held()

    t0_wall = time.time()
    traces: Dict[str, list] = {}
    for _ in range(steps):
        b = next(it)
        if held is not None:
            state, metrics = step(state, b, next(held))
        else:
            state, metrics = step(state, b)
        for k, v in metrics.items():
            traces.setdefault(k, []).append(np.asarray(v))
    stacked = {k: np.stack(v) for k, v in traces.items()}

    out = {"acc": float(sad_lib.escaped(stask, state.params["x"], gap)),
           "escape_step": sad_lib.first_escape_step(stacked["escaped"]),
           "traces": stacked,
           "wall_s": round(time.time() - t0_wall, 2)}
    good = dfn_lib.final_good(state.defense_state)
    if good is not None:
        out["caught_byz"] = int((BYZ & ~good).sum())
        out["evicted_honest"] = int((~BYZ & ~good).sum())
    return out


def profile_cell(scn: Scenario, *, repeats: int = 3) -> Dict:
    """Wall-clock phase attribution for one engine cell (DESIGN.md §15):
    AOT compile vs execute seconds plus loop-aware HLO cost attribution
    (``launch.hlo_analysis``) for the whole scan-rolled trial program.

    The trial is compiled ahead of time (``jit(...).lower(...).
    compile()``) so compile time is measured apart from the first
    execution — the plain-jit path hides it in first dispatch."""
    from repro.obs import profile as prof
    trial = campaign_engine.make_trial_fn(scn)
    knobs = campaign_engine.stack_knobs([scn])
    one = {k: v[0] for k, v in knobs.items()}
    rec = prof.profile_compiled(trial, one, repeats=repeats)
    out = rec.pop("_out")
    rec["acc"] = float(jax.device_get(out["acc"]))
    rec["steps"] = int(scn.steps)
    rec["us_per_step"] = round(1e6 * rec["execute_s"] / scn.steps, 3)
    return rec


def ideal_accuracy(task, *, steps=150, lr=0.1, batch=60, seed=0) -> float:
    """SGD on honest workers only — the paper's 'ideal accuracy'."""
    opt = make_optimizer(TrainConfig(lr=lr))
    params = tasks.student_init(task, seed=seed + 1)
    agg = agg_lib.Aggregator("mean", agg_lib.mean)
    mh = M - N_BYZ
    state = init_train_state(params, opt)
    step = make_train_step(tasks.mlp_loss, opt,
                           byz_mask=jnp.zeros((mh,), bool),
                           aggregator=agg)
    it = tasks.teacher_batches(task, batch, seed=seed, m=mh)
    tr = Trainer(state, step, it, log_every=10 ** 9, name="ideal")
    tr.run(steps, verbose=False)
    eval_b = tasks.teacher_batch(task, jax.random.PRNGKey(EVAL_KEY),
                                 EVAL_BATCH)
    return float(tasks.mlp_accuracy(tr.state.params, eval_b))
