"""Paper Figure 2(b): periodic reset of the good set (Section 5) under the
variance attack — accuracy must stay near the non-reset safeguard, proving
tolerance to transient failures / bounded ID relabeling."""

from __future__ import annotations

import json
import os

from repro.data import tasks
from benchmarks import common


def run(steps: int = 150, out_dir: str = "experiments/bench"):
    task = tasks.make_teacher_task()
    rows = []
    for name, reset in (("no_reset", 0), ("reset_40", 40),
                        ("reset_80", 80)):
        rec = common.run_experiment(task, "variance", "safeguard_double",
                                    steps=steps, reset_period=reset)
        rec["variant"] = name
        rows.append(rec)
        print(f"fig2b,{name},{rec['acc']:.4f},caught={rec['caught_byz']}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig2b.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    run()
