"""Master-side aggregation overhead: the paper claims the safeguard's
O(md) processing is negligible vs the backward pass.  Times one jitted
aggregation call per defense across model sizes d (m = 10 workers).

The gradient pytree is a realistic MULTI-LEAF layered model (per-layer
weight + bias leaves), not one monolithic array — per-leaf dispatch is
exactly the overhead the flat-buffer engine (DESIGN.md §6) removes, and a
single-leaf toy model would hide it.  Three safeguard representations are
timed against each other so the flat-engine speedup is measured, not
asserted:

  safeguard_stacked    paper-faithful stacked-pytree accumulators
                       (4 tree traversals per step: 2 accumulates + 2
                       leaf-wise Grams)
  safeguard_flat       flat (m, d_pad) buffers: in-place scatter
                       accumulate + blocked Pallas Gram kernel
                       (interpret off-TPU)
  safeguard_flat_xla   flat buffers: scatter accumulate + one XLA dot
                       (the sharded at-scale backend)
  safeguard_flat_fused flat buffers: single streamed accumulate+distance
                       Pallas kernel over the flattened gradient matrix
                       (the TPU hot path; pays a flatten on CPU)
  safeguard_sketch     CountSketch O(m r k) state (beyond paper)

Builds ONE record (raw rows + per-d safeguard entries with flat-vs-
stacked speedups) and writes it identically to
``experiments/bench/overhead.json`` and the committed repo-root baseline
``BENCH_safeguard_overhead.json`` — a single source of truth, never two
diverging formats.  Regenerate with ``python -m benchmarks.run --quick
--only overhead``.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import SafeguardConfig, init_state, safeguard_step
from repro.core import aggregators as agg_lib

M = 10
N_LAYERS = 24


def _time(fn, *args, iters=20):
    fn(*args)                              # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6     # us


def make_model(d_target: int, n_layers: int = N_LAYERS):
    """Layered params pytree (~d_target total): n_layers x {w: (h, h),
    b: (h,)} — the leaf structure of a real transformer stack at small h."""
    h = max(4, int((d_target / n_layers) ** 0.5))
    params = {f"layer_{i:02d}": {"w": jnp.zeros((h, h)),
                                 "b": jnp.zeros((h,))}
              for i in range(n_layers)}
    d = sum(leaf.size for leaf in jax.tree_util.tree_leaves(params))
    return params, d


def make_grads(params, key):
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [jax.random.normal(k, (M,) + leaf.shape)
                  for k, leaf in zip(keys, leaves)])


SAFEGUARD_VARIANTS = (
    ("safeguard_stacked", dict(engine="stacked")),
    ("safeguard_flat", dict(engine="flat", backend="pallas")),
    ("safeguard_flat_xla", dict(engine="flat", backend="xla")),
    ("safeguard_flat_fused", dict(engine="flat", backend="pallas_fused")),
    ("safeguard_sketch", dict(use_sketch=True, sketch_k=1024)),
)


def run(out_dir: str = "experiments/bench", quick: bool = False,
        baseline_path: str = "BENCH_safeguard_overhead.json"):
    sizes = (10_000, 100_000) if quick else (10_000, 100_000, 1_000_000)
    iters = 10 if quick else 20
    rows = []
    for d_target in sizes:
        params, d = make_model(d_target)
        grads = make_grads(params, jax.random.PRNGKey(0))

        reg = agg_lib.make_registry(n_byz=4, m=M)
        for name in ("mean", "coord_median", "trimmed_mean", "geo_median",
                     "krum"):
            fn = jax.jit(reg[name].fn)
            us = _time(fn, grads, iters=iters)
            rows.append({"defense": name, "d": d, "us_per_call": us})
            print(f"overhead,{name},d={d},{us:.1f}us")

        for variant, kw in SAFEGUARD_VARIANTS:
            cfg = SafeguardConfig(m=M, T0=50, T1=200, threshold_floor=1.0,
                                  **kw)
            st = init_state(cfg, params)
            fn = jax.jit(lambda s, g: safeguard_step(s, g, cfg))
            us = _time(fn, st, grads, iters=iters)
            rows.append({"defense": variant, "d": d, "us_per_call": us})
            print(f"overhead,{variant},d={d},{us:.1f}us")

    record = _build_record(rows)
    os.makedirs(out_dir, exist_ok=True)
    for path in (os.path.join(out_dir, "overhead.json"), baseline_path):
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
            f.write("\n")
    return rows


def _build_record(rows):
    """The single overhead record: raw measurements plus per-d safeguard
    entries with the flat-vs-stacked speedup (the §6 measured claim).
    Written verbatim to BOTH the experiments artifact and the committed
    repo-root baseline."""
    by = {(r["defense"], r["d"]): r["us_per_call"] for r in rows}
    ds = sorted({r["d"] for r in rows})
    record = {"m": M, "n_layers": N_LAYERS, "unit": "us_per_call",
              "rows": rows, "entries": []}
    for d in ds:
        entry = {"d": d}
        for variant, _ in SAFEGUARD_VARIANTS:
            if (variant, d) in by:
                entry[variant] = round(by[(variant, d)], 1)
        stacked = by.get(("safeguard_stacked", d))
        flat = by.get(("safeguard_flat", d))
        if stacked and flat:
            entry["flat_speedup_vs_stacked"] = round(stacked / flat, 2)
            print(f"overhead,flat_speedup_vs_stacked,d={d},"
                  f"{stacked / flat:.2f}x")
        record["entries"].append(entry)
    return record


if __name__ == "__main__":
    run()
