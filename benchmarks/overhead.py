"""Master-side aggregation overhead: the paper claims O(md) processing,
negligible vs the backward pass.  Times one jitted aggregation call per
defense across model sizes d (m = 10)."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import SafeguardConfig, init_state, safeguard_step
from repro.core import aggregators as agg_lib

M = 10


def _time(fn, *args, iters=20):
    fn(*args)                              # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6     # us


def run(out_dir: str = "experiments/bench"):
    rows = []
    for d in (10_000, 100_000, 1_000_000):
        key = jax.random.PRNGKey(0)
        grads = {"w": jax.random.normal(key, (M, d))}
        params = {"w": jnp.zeros((d,))}

        reg = agg_lib.make_registry(n_byz=4, m=M)
        for name in ("mean", "coord_median", "trimmed_mean", "geo_median",
                     "krum"):
            fn = jax.jit(reg[name].fn)
            us = _time(fn, grads)
            rows.append({"defense": name, "d": d, "us_per_call": us})
            print(f"overhead,{name},d={d},{us:.1f}us")

        for variant, kw in (("safeguard_exact", {}),
                            ("safeguard_sketch", dict(use_sketch=True,
                                                      sketch_k=1024))):
            cfg = SafeguardConfig(m=M, T0=50, T1=200, threshold_floor=1.0,
                                  **kw)
            st = init_state(cfg, params)
            fn = jax.jit(lambda s, g: safeguard_step(s, g, cfg))
            us = _time(fn, st, grads)
            rows.append({"defense": variant, "d": d, "us_per_call": us})
            print(f"overhead,{variant},d={d},{us:.1f}us")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "overhead.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    run()
