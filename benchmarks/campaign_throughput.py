"""Campaign engine throughput: per-loop ``Trainer`` trials vs the
scan+vmap engine (DESIGN.md §10) on an identical scenario slice.

The baseline is ``common.run_experiment_loop`` — one jit compile and
``steps`` python-dispatched device calls per cell, exactly what
``table1_attack_grid`` did before the engine.  The engine path groups the
same cells by ``engine.batch_key`` (scale variants + seeds share one
program) and runs each group as a single scan+vmap device program.
Trajectories are bit-identical between the two paths
(``tests/test_campaign.py``), so this measures pure dispatch/compile
economics, not a different computation.

Writes one record to ``experiments/bench/campaign_throughput.json`` AND
the committed repo-root baseline ``BENCH_campaign_throughput.json``
(single source of truth — both files get the identical record;
regenerate with ``python -m benchmarks.run --quick --only campaign``).
"""

from __future__ import annotations

import json
import os
import time

from repro.campaign import engine
from repro.campaign.scenario import scenario_id
from repro.data import tasks
from benchmarks import common

GRID_ATTACKS = ("sign_flip", "variance", "safeguard_x0.6",
                "safeguard_x0.7")
GRID_DEFENSE = "safeguard_double"


def run(out_dir: str = "experiments/bench", quick: bool = False,
        baseline_path: str = "BENCH_campaign_throughput.json"):
    steps = 40 if quick else 60
    seeds = 2 if quick else 3
    task = tasks.make_teacher_task()
    scenarios = [common.scenario_for(a, GRID_DEFENSE, steps=steps, seed=k,
                                     task=task)
                 for a in GRID_ATTACKS for k in range(seeds)]
    cells = len(scenarios)
    groups = len(engine.group_scenarios(scenarios))

    t0 = time.time()
    loop_acc = {}
    for s in scenarios:
        rec = common.run_experiment_loop(task, s.attack, GRID_DEFENSE,
                                         steps=steps, seed=s.seed)
        loop_acc[scenario_id(s)] = rec["acc"]
    loop_wall = time.time() - t0

    t0 = time.time()
    results = engine.run_scenarios(scenarios)
    vmap_wall = time.time() - t0

    drift = max(abs(results[i]["acc"] - loop_acc[i]) for i in loop_acc)
    record = {
        "grid": {"attacks": list(GRID_ATTACKS), "defense": GRID_DEFENSE,
                 "seeds": seeds, "steps": steps},
        "cells": cells,
        "engine_groups": groups,
        "loop_wall_s": round(loop_wall, 2),
        "loop_trials_per_s": round(cells / loop_wall, 3),
        "vmap_wall_s": round(vmap_wall, 2),
        "vmap_trials_per_s": round(cells / vmap_wall, 3),
        "vmap_speedup": round(loop_wall / vmap_wall, 2),
        "max_acc_drift": round(drift, 6),
    }
    print(f"campaign,cells,{cells}")
    print(f"campaign,engine_groups,{groups}")
    print(f"campaign,loop_trials_per_s,{record['loop_trials_per_s']}")
    print(f"campaign,vmap_trials_per_s,{record['vmap_trials_per_s']}")
    print(f"campaign,vmap_speedup,{record['vmap_speedup']}x")
    print(f"campaign,max_acc_drift,{record['max_acc_drift']}")

    os.makedirs(out_dir, exist_ok=True)
    for path in (os.path.join(out_dir, "campaign_throughput.json"),
                 baseline_path):
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
            f.write("\n")
    return record


if __name__ == "__main__":
    run()
