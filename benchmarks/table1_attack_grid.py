"""Paper Table 1: final accuracy per (attack x defense).

CIFAR/ResNet-20 is unavailable offline; the protocol (m=10, alpha=0.4,
attack suite, defense suite) runs on the teacher-student task.  The
qualitative claims being validated:
  * safeguard >= every baseline on (almost) every attack;
  * the variance attack collapses historyless defenses;
  * label flipping is mild; the x0.6 safeguard attack degrades the
    safeguard a little but degrades baselines far more.

The grid runs through the campaign engine (DESIGN.md §10): scenarios
sharing a program structure (all scale variants of the safeguard attack,
all seeds) become vmap lanes, so the 6x7 grid with ``seeds`` replicas is
a handful of device programs instead of ``42 * seeds`` python trials.
Rows carry ``acc_mean``/``acc_std`` over seeds; ``acc`` stays the mean
for back-compat with the single-seed json contract.
"""

from __future__ import annotations

import json
import os
import statistics
from typing import Dict, List, Sequence, Tuple

from repro.campaign import engine
from repro.campaign.scenario import (ADAPTIVE_ATTACKS, HETERO_DEFENSES,
                                     ZOO_DEFENSES, Scenario, scenario_id)
from repro.data import tasks
from benchmarks import common

# the non-IID block's skew: strong enough to separate selection-style
# rules from bounded-influence ones (DESIGN.md §13)
HETERO_ALPHA = 0.1


def build_rows(scenarios: Sequence[Scenario],
               results: Dict[str, Dict]) -> List[Dict]:
    """Collapse per-seed engine results into one row per (attack,
    defense), keyed explicitly — never by row order — with multi-seed
    accuracy statistics."""
    by_cell: Dict[Tuple[str, str], List[Dict]] = {}
    for s in scenarios:
        by_cell.setdefault((s.attack, s.defense), []).append(
            results[scenario_id(s)])
    rows = []
    for (attack, defense), recs in by_cell.items():
        accs = [float(r["acc"]) for r in recs]
        mean = statistics.fmean(accs)
        std = statistics.pstdev(accs) if len(accs) > 1 else 0.0
        row = {"attack": attack, "defense": defense, "acc": mean,
               "acc_mean": mean, "acc_std": std, "seeds": len(accs)}
        if "caught_byz" in recs[0]:
            row["caught_byz"] = max(r["caught_byz"] for r in recs)
            row["evicted_honest"] = max(r["evicted_honest"] for r in recs)
        rows.append(row)
    return rows


def run(steps: int = 150, out_dir: str = "experiments/bench",
        seeds: int = 1, adaptive: bool = True, zoo: bool = True,
        hetero: bool = True):
    """``adaptive=True`` appends the feedback-coupled adversary rows
    (DESIGN.md §11) below the paper's static grid; ``zoo=True`` appends
    the history-aware defense-zoo columns (DESIGN.md §12) — centered
    clipping must survive the variance attack that degrades ``mean``;
    ``hetero=True`` appends a non-IID block (Dirichlet label skew at
    alpha=0.1, DESIGN.md §13) over the hetero defense suite."""
    task = tasks.make_teacher_task()
    ideal = common.ideal_accuracy(task, steps=steps)
    attacks = list(common.ATTACKS) + (list(ADAPTIVE_ATTACKS) if adaptive
                                      else [])
    defenses = list(common.DEFENSES) + (list(ZOO_DEFENSES) if zoo else [])
    scenarios = [common.scenario_for(a, d, steps=steps, seed=k, task=task)
                 for a in attacks for d in defenses
                 for k in range(seeds)]
    results = engine.run_scenarios(scenarios, verbose=True)
    rows = build_rows(scenarios, results)
    cells = {(r["attack"], r["defense"]): r for r in rows}
    for attack in attacks:
        for defense in defenses:
            r = cells[(attack, defense)]
            print(f"table1,{attack},{defense},{r['acc']:.4f},"
                  f"caught={r.get('caught_byz', '-')}")
    # non-IID block: same protocol, Dirichlet label-skewed honest workers
    hetero_rows = []
    if hetero:
        h_attacks = ("none", "variance")
        h_scenarios = [
            common.scenario_for(a, d, steps=steps, seed=k, task=task,
                                hetero="dirichlet",
                                hetero_alpha=HETERO_ALPHA)
            for a in h_attacks for d in HETERO_DEFENSES
            for k in range(seeds)]
        h_results = engine.run_scenarios(h_scenarios, verbose=True)
        hetero_rows = build_rows(h_scenarios, h_results)
        h_cells = {(r["attack"], r["defense"]): r for r in hetero_rows}
        for attack in h_attacks:
            for defense in HETERO_DEFENSES:
                r = h_cells[(attack, defense)]
                print(f"table1-hetero,{attack},{defense},{r['acc']:.4f},"
                      f"caught={r.get('caught_byz', '-')}")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "table1.json"), "w") as f:
        json.dump({"ideal": ideal, "seeds": seeds, "rows": rows,
                   "hetero_alpha": HETERO_ALPHA if hetero else None,
                   "hetero_rows": hetero_rows}, f, indent=1)

    # markdown table — mean±std over seeds
    print(f"\nideal accuracy (honest-only SGD): {ideal:.4f}\n")
    header = "| attack | " + " | ".join(defenses) + " |"
    print(header)
    print("|" + "---|" * (len(defenses) + 1))
    for attack in attacks:
        parts = []
        for defense in defenses:
            r = cells[(attack, defense)]
            if seeds > 1:
                parts.append(f"{r['acc_mean']:.3f}±{r['acc_std']:.3f}")
            else:
                parts.append(f"{r['acc']:.3f}")
        print(f"| {attack} | " + " | ".join(parts) + " |")

    if hetero_rows:
        h_cells = {(r["attack"], r["defense"]): r for r in hetero_rows}
        print(f"\nnon-IID honest workers (Dirichlet alpha={HETERO_ALPHA})\n")
        print("| attack | " + " | ".join(HETERO_DEFENSES) + " |")
        print("|" + "---|" * (len(HETERO_DEFENSES) + 1))
        for attack in ("none", "variance"):
            parts = []
            for defense in HETERO_DEFENSES:
                r = h_cells[(attack, defense)]
                if seeds > 1:
                    parts.append(f"{r['acc_mean']:.3f}±{r['acc_std']:.3f}")
                else:
                    parts.append(f"{r['acc']:.3f}")
            print(f"| {attack} | " + " | ".join(parts) + " |")
    return rows + hetero_rows


if __name__ == "__main__":
    run()
