"""Paper Table 1: final accuracy per (attack x defense).

CIFAR/ResNet-20 is unavailable offline; the protocol (m=10, alpha=0.4,
attack suite, defense suite) runs on the teacher-student task.  The
qualitative claims being validated:
  * safeguard >= every baseline on (almost) every attack;
  * the variance attack collapses historyless defenses;
  * label flipping is mild; the x0.6 safeguard attack degrades the
    safeguard a little but degrades baselines far more.
"""

from __future__ import annotations

import json
import os

from repro.data import tasks
from benchmarks import common


def run(steps: int = 150, out_dir: str = "experiments/bench"):
    task = tasks.make_teacher_task()
    ideal = common.ideal_accuracy(task, steps=steps)
    rows = []
    for attack in common.ATTACKS:
        for defense in common.DEFENSES:
            rec = common.run_experiment(task, attack, defense, steps=steps)
            rows.append(rec)
            print(f"table1,{attack},{defense},{rec['acc']:.4f},"
                  f"caught={rec.get('caught_byz', '-')}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "table1.json"), "w") as f:
        json.dump({"ideal": ideal, "rows": rows}, f, indent=1)

    # markdown table
    print(f"\nideal accuracy (honest-only SGD): {ideal:.4f}\n")
    header = "| attack | " + " | ".join(common.DEFENSES) + " |"
    print(header)
    print("|" + "---|" * (len(common.DEFENSES) + 1))
    for attack in common.ATTACKS:
        cells = [f"{r['acc']:.3f}" for r in rows if r["attack"] == attack]
        print(f"| {attack} | " + " | ".join(cells) + " |")
    return rows


if __name__ == "__main__":
    run()
