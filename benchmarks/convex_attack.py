"""Appendix C.3: the burst attack against the *unwindowed* concentration
filter (the convex algorithm of Alistarh et al. 2018, emulated as a single
safeguard whose window never resets and whose threshold is calibrated to a
full honest run).  The attacker behaves honestly, then scales gradients by
-5 for a contiguous burst sized to stay under the whole-run threshold.

Expected: the unwindowed filter fails to evict (or the run diverges),
while the paper's windowed safeguard catches the burst.

Both variants route through the campaign engine (DESIGN.md §10) as
Scenario cells — the raw per-step Trainer loop this file used to carry
lives on as ``common.run_experiment_loop(..., t0/t1/floor/burst_*)``,
the numerics oracle ``tests/test_campaign.py::
test_convex_attack_port_matches_legacy_loop`` pins this port against.
"""

from __future__ import annotations

import json
import os

from benchmarks import common
from repro.campaign import engine
from repro.campaign.scenario import Scenario, scenario_id

STEPS = 200
BURST_START, BURST_LENGTH = 80, 40
# name -> (T0, T1, threshold_floor): "windowed" is the paper's sliding
# windows; "unwindowed" emulates the convex filter — window longer than
# the run, threshold calibrated so an honest full run would pass
VARIANTS = {
    "windowed": (20, 60, 0.1),
    "unwindowed": (10 ** 6, 10 ** 6, 12.0),
}


def variant_scenario(name: str, *, steps: int = STEPS,
                     seed: int = 0) -> Scenario:
    t0, t1, floor = VARIANTS[name]
    return Scenario(attack="burst", defense="safeguard_double", m=common.M,
                    n_byz=common.N_BYZ, steps=steps, seed=seed, lr=0.1,
                    batch=100, T0=t0, T1=t1, threshold_floor=floor,
                    burst_start=BURST_START, burst_length=BURST_LENGTH)


def run(steps: int = STEPS, out_dir: str = "experiments/bench"):
    scns = {name: variant_scenario(name, steps=steps) for name in VARIANTS}
    res = engine.run_scenarios(list(scns.values()))
    results = {}
    for name, s in scns.items():
        rec = res[scenario_id(s)]
        results[name] = {"acc": float(rec["acc"]),
                         "caught_byz": int(rec["caught_byz"])}
        print(f"convex_attack,{name},acc={results[name]['acc']:.4f},"
              f"caught={results[name]['caught_byz']}")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "convex_attack.json"), "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    run()
