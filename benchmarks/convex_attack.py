"""Appendix C.3: the burst attack against the *unwindowed* concentration
filter (the convex algorithm of Alistarh et al. 2018, emulated as a single
safeguard whose window never resets and whose threshold is calibrated to a
full honest run).  The attacker behaves honestly, then scales gradients by
-5 for a contiguous burst sized to stay under the whole-run threshold.

Expected: the unwindowed filter fails to evict (or the run diverges),
while the paper's windowed safeguard catches the burst.
"""

from __future__ import annotations

import json
import os

import jax.numpy as jnp

from repro.data import tasks
from repro.core import attacks as atk_lib
from benchmarks import common


def run(steps: int = 200, out_dir: str = "experiments/bench"):
    task = tasks.make_teacher_task()
    burst = atk_lib.Attack(
        "burst", atk_lib.make_burst(start=80, length=40, burst_scale=5.0))

    import repro.core.attacks as atk
    results = {}
    for name, (t0, t1, floor) in {
        # windowed (the paper): short windows catch the burst
        "windowed": (20, 60, 0.1),
        # unwindowed emulation: window longer than the run, threshold
        # calibrated so an honest full run would pass (large floor)
        "unwindowed": (10 ** 6, 10 ** 6, 12.0),
    }.items():
        from repro.core import SafeguardConfig
        from repro.configs.base import TrainConfig
        from repro.optim import make_optimizer
        from repro.train import Trainer, init_train_state, make_train_step
        sg_cfg = SafeguardConfig(m=common.M, T0=t0, T1=t1,
                                 threshold_floor=floor)
        opt = make_optimizer(TrainConfig(lr=0.1))
        params = tasks.student_init(task)
        state = init_train_state(params, opt, sg_cfg=sg_cfg, attack=burst)
        step = make_train_step(tasks.mlp_loss, opt, byz_mask=common.BYZ,
                               sg_cfg=sg_cfg, attack=burst)
        it = tasks.teacher_batches(task, 100, m=common.M)
        tr = Trainer(state, step, it, log_every=10 ** 9, name=name)
        tr.run(steps, verbose=False)
        import jax
        eval_b = tasks.teacher_batch(task, jax.random.PRNGKey(10_000), 4000)
        acc = float(tasks.mlp_accuracy(tr.state.params, eval_b))
        caught = int((common.BYZ & ~tr.state.sg_state.good).sum())
        results[name] = {"acc": acc, "caught_byz": caught}
        print(f"convex_attack,{name},acc={acc:.4f},caught={caught}")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "convex_attack.json"), "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    run()
