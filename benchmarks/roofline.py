"""Roofline analysis (deliverable g): read the dry-run artifacts and derive
the three roofline terms per (arch x shape x mesh x variant):

    compute    = FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bandwidth
    collective = collective_bytes_per_device / ICI_link_bandwidth

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
``cost_analysis`` reports the post-partitioning per-device module, so all
three terms are per-chip seconds directly.

Also reports MODEL_FLOPS (6*N*D train / 2*N*D inference, N = active
params) against total HLO FLOPs — the useful-compute fraction that
exposes remat/redundancy waste — and names the dominant term.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

_SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def model_flops(arch: str, shape: str) -> float:
    import repro.configs as C
    cfg = C.get(arch)
    n_active = cfg.active_param_count()
    seq, batch, kind = _SHAPES[shape]
    if kind == "train":
        return 6.0 * n_active * seq * batch
    if kind == "prefill":
        return 2.0 * n_active * seq * batch
    return 2.0 * n_active * batch          # one token per sequence


def analyze(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    n_dev = rec["n_devices"]
    # prefer loop-aware accounting (XLA's cost_analysis counts while-loop
    # bodies once; hlo_analysis.py multiplies by trip counts)
    flops = (rec.get("flops_per_device_loop_aware")
             or rec["flops_per_device"])
    hbm = (rec.get("hbm_bytes_per_device_loop_aware")
           or rec["bytes_per_device"])
    coll_b = (rec.get("collective_bytes_loop_aware")
              or rec["collective_bytes"])
    t_comp = flops / PEAK_FLOPS
    t_mem = hbm / HBM_BW
    t_coll = sum(coll_b.values()) / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = flops * n_dev
    mem = rec["memory"]
    live = (mem["argument_bytes"] + mem["temp_bytes"]
            + mem["output_bytes"] - mem["alias_bytes"])
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "variant")},
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_step_s": max(terms.values()),
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_fraction": mf / hlo_total if hlo_total else 0.0,
        "live_bytes_per_device": live,
        "collective_bytes": coll_b,
    }


def load_all(dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(f))
        a = analyze(rec)
        if a is not None:
            out.append(a)
        else:
            out.append({**{k: rec.get(k) for k in
                           ("arch", "shape", "mesh", "variant")},
                        "skipped": rec.get("reason", "")})
    return out


def markdown_table(rows: List[Dict], mesh: str = "16x16",
                   variant: str = "exact") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bound | "
        "useful % | live GiB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != mesh or r.get("variant") != variant:
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {100 * r['useful_fraction']:.0f}% | "
            f"{r['live_bytes_per_device'] / 2**30:.1f} |")
    return "\n".join(lines)


def run(out_dir: str = "experiments/bench"):
    rows = load_all()
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)
    ok = [r for r in rows if "skipped" not in r]
    for r in ok:
        if r["mesh"] == "16x16" and r["variant"] == "exact":
            print(f"roofline,{r['arch']},{r['shape']},"
                  f"{r['dominant']},{r['bound_step_s']:.3e}s,"
                  f"useful={100 * r['useful_fraction']:.0f}%")
    print()
    print(markdown_table(rows))
    return rows


if __name__ == "__main__":
    run()
