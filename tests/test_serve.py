"""Serving loop: generation shapes, determinism, and greedy consistency
with step-by-step decoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import transformer as T
from repro.train.serve import generate


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m",
                                  "recurrentgemma-2b"])
def test_generate_shapes_and_determinism(arch, rng):
    cfg = C.get_smoke(arch)
    params = T.init_params(cfg, rng)
    prompt = jax.random.randint(rng, (3, 12), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    toks1 = generate(params, cfg, prompt, n_tokens=6, max_seq=18)
    toks2 = generate(params, cfg, prompt, n_tokens=6, max_seq=18)
    assert toks1.shape == (3, 6)
    np.testing.assert_array_equal(np.asarray(toks1), np.asarray(toks2))
    assert int(toks1.max()) < cfg.vocab_size


def test_generate_matches_manual_greedy(rng):
    cfg = C.get_smoke("tinyllama-1.1b")
    params = T.init_params(cfg, rng)
    prompt = jax.random.randint(rng, (2, 8), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    toks = np.asarray(generate(params, cfg, prompt, n_tokens=4, max_seq=12))
    # manual greedy
    logits, cache = T.prefill(params, cfg, prompt, max_seq=12)
    cur = logits.argmax(-1).astype(jnp.int32)
    out = [np.asarray(cur)]
    for _ in range(3):
        logits, cache = T.decode_step(params, cfg, cur[:, None], cache)
        cur = logits.argmax(-1).astype(jnp.int32)
        out.append(np.asarray(cur))
    np.testing.assert_array_equal(toks, np.stack(out, 1))


def test_generate_sampling_temperature(rng):
    cfg = C.get_smoke("tinyllama-1.1b")
    params = T.init_params(cfg, rng)
    prompt = jax.random.randint(rng, (2, 8), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    t1 = generate(params, cfg, prompt, n_tokens=8, max_seq=16,
                  rng=jax.random.PRNGKey(1), temperature=2.0)
    t2 = generate(params, cfg, prompt, n_tokens=8, max_seq=16,
                  rng=jax.random.PRNGKey(2), temperature=2.0)
    assert not np.array_equal(np.asarray(t1), np.asarray(t2))
