"""Safeguard core behaviour: the paper's qualitative guarantees at test
scale — honest workers are never evicted, history-based attacks are caught,
windows reset, and the aggregate ignores evicted workers."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import SafeguardConfig, init_state, safeguard_step
from repro.core import attacks as atk
from repro.core import tree_utils as tu

M = 10
PARAMS = {"w": jnp.zeros((20, 5)), "b": jnp.zeros((5,))}


def honest_grads(key, mu=1.0, sigma=0.05):
    k1, k2 = jax.random.split(key)
    return {
        "w": mu + sigma * jax.random.normal(k1, (M, 20, 5)),
        "b": mu + sigma * jax.random.normal(k2, (M, 5)),
    }


def run(cfg, attack_fn, byz_mask, steps, key=None, astate=None):
    st = init_state(cfg, PARAMS)
    key = key if key is not None else jax.random.PRNGKey(0)
    step = jax.jit(lambda s, g: safeguard_step(s, g, cfg))
    infos = []
    for t in range(steps):
        key, k = jax.random.split(key)
        g = honest_grads(k)
        g, astate = attack_fn(g, byz_mask, astate, jnp.int32(t), k)
        st, agg, info = step(st, g)
        infos.append(info)
    return st, agg, infos


def test_honest_never_evicted():
    cfg = SafeguardConfig(m=M, T0=20, T1=60, threshold_floor=0.5)
    byz = jnp.zeros((M,), bool)
    st, _, _ = run(cfg, atk.attack_none, byz, 120)
    assert bool(st.good.all())


def test_sign_flip_caught_and_honest_kept():
    cfg = SafeguardConfig(m=M, T0=20, T1=60, threshold_floor=0.5)
    byz = jnp.arange(M) < 4
    st, _, _ = run(cfg, atk.attack_sign_flip, byz, 60)
    assert bool((~st.good[:4]).all()), "sign-flippers must be evicted"
    assert bool(st.good[4:].all()), "honest workers must survive"


def test_eviction_is_permanent_within_window():
    cfg = SafeguardConfig(m=M, T0=50, T1=200, threshold_floor=0.5)
    byz = jnp.arange(M) < 3
    # burst attack active only in steps [10, 25): after it stops, workers
    # must STAY evicted (no reset period configured)
    attack = atk.make_burst(start=10, length=15, burst_scale=5.0)
    st, _, _ = run(cfg, attack, byz, 45)
    assert bool((~st.good[:3]).all())
    assert bool(st.good[3:].all())


def test_reset_period_restores_workers():
    cfg = SafeguardConfig(m=M, T0=10, T1=20, threshold_floor=0.5,
                          reset_period=30)
    byz = jnp.arange(M) < 3
    attack = atk.make_burst(start=0, length=10, burst_scale=5.0)
    st, _, infos = run(cfg, attack, byz, 35)
    # evicted during the burst...
    assert not bool(infos[12]["good"][:3].all())
    # ...but restored at the reset and kept (attack long over)
    assert bool(st.good.all())


def test_reset_clears_evicted_at_and_reports_restored():
    """A Section-5 periodic reset must clear the ``evicted_at`` diagnostic
    of the workers it restores (otherwise post-reset eviction times and
    the fig2b trace misreport) and surface the restore in the info dict."""
    cfg = SafeguardConfig(m=M, T0=10, T1=20, threshold_floor=0.5,
                          reset_period=30)
    byz = jnp.arange(M) < 3
    attack = atk.make_burst(start=0, length=10, burst_scale=5.0)
    st, _, infos = run(cfg, attack, byz, 35)
    # evicted during the burst, with recorded eviction times...
    assert not bool(infos[12]["good"][:3].all())
    # ...the reset at t=30 reports exactly the restored workers...
    restored = infos[30]["restored"]
    assert bool(restored[:3].any()) and not bool(restored[3:].any())
    assert not bool(infos[29]["restored"].any())
    # ...and clears their eviction-time diagnostic (attack long over, so
    # nobody is re-evicted afterwards)
    assert bool(st.good.all())
    assert bool((st.evicted_at == -1).all())


def test_aggregate_excludes_evicted():
    cfg = SafeguardConfig(m=M, T0=20, T1=60, threshold_floor=0.5,
                          aggregate_prefilter=False)
    byz = jnp.arange(M) < 4
    st, agg, _ = run(cfg, atk.attack_sign_flip, byz, 60)
    # after eviction the aggregate is the honest mean (~mu=1.0)
    assert abs(float(agg["w"].mean()) - 1.0) < 0.1


def test_variance_attack_caught_with_large_shift():
    # z=1.5 (the paper's 50-node setting) drifts linearly and must be caught
    cfg = SafeguardConfig(m=M, T0=50, T1=150, threshold_floor=0.2)
    byz = jnp.arange(M) < 4
    attack = atk.make_variance_attack(z_max=1.5)
    st, _, _ = run(cfg, attack, byz, 150)
    assert bool((~st.good[:4]).all())
    assert bool(st.good[4:].all())


def test_detection_statistic_linear_vs_sqrt():
    """Paper Figure 2(a): ||B_i - B_med|| grows ~linearly for a variance
    attacker vs ~sqrt(t) for honest workers."""
    cfg = SafeguardConfig(m=M, T0=10**6, T1=10**6,
                          threshold_floor=10**6)   # filter disabled
    byz = jnp.arange(M) < 4
    attack = atk.make_variance_attack(z_max=1.5)
    st = init_state(cfg, PARAMS)
    key = jax.random.PRNGKey(1)
    astate = None
    step = jax.jit(lambda s, g: safeguard_step(s, g, cfg))
    at = {}
    for t in range(200):
        key, k = jax.random.split(key)
        g, astate = attack(honest_grads(k), byz, astate, jnp.int32(t), k)
        st, _, info = step(st, g)
        if t in (49, 199):
            at[t] = info["dist_to_med_B"]
    byz_growth = float(at[199][0] / at[49][0])
    honest_growth = float(at[199][5] / jnp.maximum(at[49][5], 1e-6))
    assert byz_growth > 3.0            # ~linear: 200/50 = 4x
    assert byz_growth > 1.5 * honest_growth


def test_single_vs_double_mode():
    cfg_s = SafeguardConfig(m=M, T0=30, T1=30, mode="single",
                            threshold_floor=0.5)
    byz = jnp.arange(M) < 4
    st, _, _ = run(cfg_s, atk.attack_sign_flip, byz, 60)
    assert bool((~st.good[:4]).all())
    assert st.A is None


def test_theoretical_rule():
    t0, t1 = SafeguardConfig.theoretical_thresholds(20, 60, M, V=0.2)
    cfg = SafeguardConfig(m=M, T0=20, T1=60, rule="theoretical",
                          thresh0=t0, thresh1=t1)
    byz = jnp.arange(M) < 4
    st, _, _ = run(cfg, atk.attack_none, byz, 60)
    assert bool(st.good.all())
    st, _, _ = run(cfg, atk.attack_sign_flip, byz, 60)
    assert bool((~st.good[:4]).all())
    assert bool(st.good[4:].all())


def test_sketched_matches_exact_decisions():
    byz = jnp.arange(M) < 4
    results = {}
    for sketch in (False, True):
        cfg = SafeguardConfig(m=M, T0=20, T1=60, threshold_floor=0.5,
                              use_sketch=sketch, sketch_k=512,
                              sketch_reps=4)
        st, _, _ = run(cfg, atk.attack_sign_flip, byz, 60)
        results[sketch] = st.good
    assert bool((results[False] == results[True]).all())


def test_gaussian_perturbation_applied():
    cfg = SafeguardConfig(m=M, T0=20, T1=60, threshold_floor=0.5, nu=0.5)
    st = init_state(cfg, PARAMS)
    g = honest_grads(jax.random.PRNGKey(0), sigma=0.0)
    _, agg1, _ = safeguard_step(st, g, cfg, jax.random.PRNGKey(1))
    _, agg2, _ = safeguard_step(st, g, cfg, jax.random.PRNGKey(2))
    assert not jnp.allclose(agg1["w"], agg2["w"])
    assert float(jnp.abs(agg1["w"] - 1.0).mean()) < 3 * 0.5
