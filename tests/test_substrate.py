"""Substrate tests: optimizers, schedules, data pipeline, checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.data import pipeline as data_lib
from repro.data import tasks
from repro.optim import make_optimizer, global_norm, clip_by_global_norm
from repro.optim.schedules import make_schedule
from repro import checkpoint as ckpt


def quad_params():
    return {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.0)}


def quad_grads(params):
    return jax.grad(lambda p: (p["w"] ** 2).sum() + p["b"] ** 2)(params)


def run_opt(cfg, steps=200):
    opt = make_optimizer(cfg)
    params = quad_params()
    state = opt.init(params)
    for t in range(steps):
        g = quad_grads(params)
        params, state = opt.update(g, state, params, jnp.int32(t))
    return params


@pytest.mark.parametrize("cfg", [
    TrainConfig(lr=0.1, optimizer="sgd"),
    TrainConfig(lr=0.1, momentum=0.9, optimizer="sgd"),
    TrainConfig(lr=0.05, optimizer="adam"),
])
def test_optimizers_minimize_quadratic(cfg):
    params = run_opt(cfg)
    assert float(global_norm(params)) < 1e-2


def test_weight_decay_shrinks():
    p1 = run_opt(TrainConfig(lr=0.01, optimizer="sgd"), steps=20)
    p2 = run_opt(TrainConfig(lr=0.01, weight_decay=1.0, optimizer="sgd"),
                 steps=20)
    assert float(global_norm(p2)) < float(global_norm(p1))


def test_grad_clip():
    g = {"w": jnp.array([300.0, 400.0])}
    clipped, norm = clip_by_global_norm(g, 5.0)
    assert abs(float(norm) - 500.0) < 1e-3
    np.testing.assert_allclose(np.asarray(clipped["w"]), [3.0, 4.0],
                               rtol=1e-5)
    g2, _ = clip_by_global_norm({"w": jnp.array([0.3, 0.4])}, 5.0)
    np.testing.assert_allclose(np.asarray(g2["w"]), [0.3, 0.4], rtol=1e-6)


def test_schedule_warmup_cosine():
    cfg = TrainConfig(lr=1.0, warmup_steps=10, schedule="cosine",
                      total_steps=110)
    lr = make_schedule(cfg)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(110))) < 1e-6
    assert float(lr(jnp.int32(60))) < float(lr(jnp.int32(20)))


# --------------------------------------------------------------------------
# data
# --------------------------------------------------------------------------

def test_lm_batches_shapes_and_determinism():
    it1 = data_lib.lm_batches(100, 8, 16, seed=7, m=4)
    it2 = data_lib.lm_batches(100, 8, 16, seed=7, m=4)
    b1, b2 = next(it1), next(it2)
    assert b1["tokens"].shape == (4, 2, 16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = next(it1)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_label_flip_applies_to_byz_workers_only():
    flip = jnp.array([True, False, False, False])
    it = data_lib.lm_batches(100, 8, 16, seed=1, m=4, flip_mask=flip)
    it0 = data_lib.lm_batches(100, 8, 16, seed=1, m=4)
    b, b0 = next(it), next(it0)
    np.testing.assert_array_equal(
        np.asarray(b["tokens"][0]), 99 - np.asarray(b0["tokens"][0]))
    np.testing.assert_array_equal(np.asarray(b["tokens"][1:]),
                                  np.asarray(b0["tokens"][1:]))


def test_stub_batches():
    it = data_lib.stub_batches(32, 50, 6, 8, m=3)
    b = next(it)
    assert b["embeds"].shape == (3, 2, 8, 32)
    assert b["labels"].shape == (3, 2, 8)
    assert int(b["labels"].max()) < 50


def test_teacher_task_learnable():
    task = tasks.make_teacher_task(d_in=16, d_hidden=32, n_classes=4)
    b = tasks.teacher_batch(task, jax.random.PRNGKey(0), 512)
    # teacher itself achieves 100%
    assert float(tasks.mlp_accuracy(task.teacher, b)) == 1.0
    # labels are non-degenerate
    counts = np.bincount(np.asarray(b["y"]), minlength=4)
    assert (counts > 10).all()


# --------------------------------------------------------------------------
# checkpoint
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "blocks": [{"a": jnp.ones((2,))},
                                  {"a": jnp.zeros((2,))}]},
            "step": jnp.int32(7)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, tree, metadata={"note": "test"})
    ckpt.save(d, 12, tree)
    assert ckpt.latest_step(d) == 12
    restored, meta = ckpt.restore(d, 7)
    assert meta["metadata"]["note"] == "test"
    np.testing.assert_array_equal(restored["params"]["w"],
                                  np.arange(6.0).reshape(2, 3))
    np.testing.assert_array_equal(restored["params"]["blocks"][1]["a"],
                                  np.zeros((2,)))
    assert int(restored["step"]) == 7


def test_checkpoint_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "nope"))
