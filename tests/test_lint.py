"""repro.lint — the JAX-aware static analyzer (DESIGN.md §16).

Three layers of coverage:

  * every tier-1 pass fires on its committed known-bad fixture under
    ``tests/lint_fixtures/`` and fires *only* its own rule;
  * the tier-2 jaxpr walks fire on traced fixture functions, and the
    recompilation detector provably catches an injected
    knob-into-program-structure mutation of a real campaign trial;
  * HEAD is clean: the tier-1 analyzer reports nothing on the tree
    (the full tier-2 baseline diff runs in `make lint` / CI, not here —
    it traces all ~70 campaign programs)."""

import json
import shutil
from pathlib import Path

import jax
import pytest

from repro.lint import ast_passes, cli, jaxpr_passes
from repro.lint.allowlist import Allowlist
from repro.lint.report import Violation, render

ROOT = Path(__file__).resolve().parents[1]
FIXTURES = ROOT / "tests" / "lint_fixtures"

TIER1_FIXTURES = {
    "fx_traced_branch.py": "traced-branch",
    "fx_host_cast.py": "host-cast",
    "fx_np_in_trace.py": "np-in-trace",
    "fx_host_callback_bad.py": "np-in-trace",
    "fx_key_reuse.py": "key-reuse",
    "fx_knob_literal.py": "knob-literal",
    "fx_obs_key.py": "obs-key",
}


def _run_tier1_passes(mod):
    knobs = ast_passes.knob_names(ROOT)
    registered = ast_passes.registered_obs_keys(ROOT)
    out = []
    out.extend(ast_passes.check_trace_bodies(mod))
    out.extend(ast_passes.check_key_reuse(mod))
    out.extend(ast_passes.check_knob_literals(mod, knobs))
    out.extend(ast_passes.check_obs_keys(mod, registered))
    return out


@pytest.mark.parametrize("fixture,rule", sorted(TIER1_FIXTURES.items()))
def test_fixture_triggers_exactly_its_rule(fixture, rule):
    mod = ast_passes.load_modules(ROOT, [FIXTURES / fixture])[0]
    violations = _run_tier1_passes(mod)
    assert violations, f"{fixture} must trigger {rule}"
    assert {v.rule for v in violations} == {rule}


def test_host_callback_bodies_are_exempt():
    """np / float() inside a function handed to io_callback /
    jax.debug.callback is host-side work, not a trace violation."""
    mod = ast_passes.load_modules(
        ROOT, [FIXTURES / "fx_host_callback_good.py"])[0]
    assert _run_tier1_passes(mod) == []


def test_tap_surface_is_lint_registered():
    """The obs-key closure covers the tap surface: every TAP key is
    parsed from schema.py, and trainer.py's `payload` writes are
    checked against it (HEAD-clean test would catch an unregistered
    key; here we check the registry side directly)."""
    registered = ast_passes.registered_obs_keys(ROOT)
    from repro.obs import schema
    assert registered["tap"] == set(schema.TAP)
    assert "step" in registered["tap"]


def test_fixture_report_format_is_file_line():
    mod = ast_passes.load_modules(
        ROOT, [FIXTURES / "fx_key_reuse.py"])[0]
    line = render(ast_passes.check_key_reuse(mod)).splitlines()[0]
    # precise file:line:col prefix, then the rule id
    assert line.startswith("tests/lint_fixtures/fx_key_reuse.py:8:")
    assert " key-reuse " in line


def test_scenario_hash_fixture(tmp_path):
    fake = tmp_path / "src" / "repro" / "campaign"
    fake.mkdir(parents=True)
    shutil.copy(FIXTURES / "fx_scenario_field.py", fake / "scenario.py")
    violations = ast_passes.check_scenario_hash(
        tmp_path, FIXTURES / "scenario_fields_baseline.json")
    assert [v.rule for v in violations] == ["scenario-hash"]
    assert "new_knob" in violations[0].message


def test_scenario_hash_declaration_matches_head():
    baseline = json.loads(cli.SCENARIO_BASELINE.read_text())["fields"]
    assert baseline == ast_passes.scenario_fields(ROOT)


def test_head_is_clean_tier1():
    allow = Allowlist.load(ROOT)
    kept, _ = allow.filter(cli.run_tier1(ROOT))
    kept.extend(allow.stale_entries())
    assert not kept, "\n" + render(kept)


def test_nested_lambda_violation_reported_once(tmp_path):
    """Nested bodies are pruned from the enclosing walk: a .item()
    inside a lambda inside a jitted fn is one violation, not two."""
    bad = tmp_path / "fx.py"
    bad.write_text(
        "import jax\n\n\n"
        "def make_step():\n"
        "    def step_fn(x):\n"
        "        f = lambda y: y.item()\n"
        "        return f(x)\n"
        "    return jax.jit(step_fn)\n")
    mod = ast_passes.load_modules(tmp_path, [bad])[0]
    violations = ast_passes.check_trace_bodies(mod)
    assert [v.rule for v in violations] == ["host-cast"]


def test_closure_taint_reaches_nested_lambda(tmp_path):
    """An enclosing trace body's param stays tainted inside a nested
    lambda (pruning must not lose closure-captured tracers)."""
    bad = tmp_path / "fx.py"
    bad.write_text(
        "import jax\n\n\n"
        "def make_step():\n"
        "    def step_fn(x):\n"
        "        f = lambda y: float(x) + y\n"
        "        return f(0.0)\n"
        "    return jax.jit(step_fn)\n")
    mod = ast_passes.load_modules(tmp_path, [bad])[0]
    violations = ast_passes.check_trace_bodies(mod)
    assert [v.rule for v in violations] == ["host-cast"]
    assert "float" in violations[0].message


def test_inline_allow_suppresses(tmp_path):
    bad = tmp_path / "fx.py"
    bad.write_text(
        "import jax\n\n\n"
        "def make_step():\n"
        "    def step_fn(state, grads):\n"
        "        if grads > 0:  # lint: allow(traced-branch)\n"
        "            state = state + grads\n"
        "        return state\n"
        "    return jax.jit(step_fn)\n")
    mod = ast_passes.load_modules(tmp_path, [bad])[0]
    assert not ast_passes.check_trace_bodies(mod)


def test_allowlist_stale_entry_reported(tmp_path):
    (tmp_path / "lint-allowlist.txt").write_text(
        "key-reuse  src/never/exists.py\n")
    allow = Allowlist.load(tmp_path)
    kept, _ = allow.filter([])
    stale = allow.stale_entries()
    assert kept == [] and len(stale) == 1
    assert stale[0].rule == "stale-allow"


def test_stale_detection_only_on_full_runs(tmp_path):
    """A partial run (CI-style `--tier 2`) must not call a tier-1
    allowlist entry stale — only `--tier all` sees every violation."""
    (tmp_path / "lint-allowlist.txt").write_text(
        "knob-literal  src/repro/core/safeguard.py  threshold_scale\n")
    for tier, expect_stale in (("1", 0), ("2", 0), ("all", 1)):
        allow = Allowlist.load(tmp_path)
        kept, suppressed = cli.apply_allowlist([], allow, tier)
        assert suppressed == []
        assert len(kept) == expect_stale, tier
        if kept:
            assert kept[0].rule == "stale-allow"


# ---------------------------------------------------------------------------
# tier 2
# ---------------------------------------------------------------------------

def _fx_tier2():
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "fx_tier2", FIXTURES / "fx_tier2.py")
    m = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(m)
    return m


def test_sqrt_diff_walk_fires_on_fixture():
    m = _fx_tier2()
    bad = jax.make_jaxpr(m.unclamped_dist)(1.0, 2.0)
    good = jax.make_jaxpr(m.clamped_dist)(1.0, 2.0)
    assert [v.rule for v in
            jaxpr_passes.find_unclamped_sqrt(bad, "fx")] == ["sqrt-diff"]
    assert not jaxpr_passes.find_unclamped_sqrt(good, "fx")


def test_f64_walk_fires_on_fixture():
    m = _fx_tier2()
    with jax.experimental.enable_x64():
        bad = jax.make_jaxpr(m.promotes_f64)(1.0)
    assert [v.rule for v in
            jaxpr_passes.find_f64(bad, "fx")] == ["f64"]
    clean = jax.make_jaxpr(m.clamped_dist)(1.0, 2.0)
    assert not jaxpr_passes.find_f64(clean, "fx")


def test_rng_counts_stable_and_nonempty():
    def draw(key):
        k1, k2 = jax.random.split(key)
        return jax.random.normal(k1, (2,)) + jax.random.uniform(k2, (2,))

    c1 = jaxpr_passes.rng_counts(
        jax.make_jaxpr(draw)(jax.random.PRNGKey(0)))
    c2 = jaxpr_passes.rng_counts(
        jax.make_jaxpr(draw)(jax.random.PRNGKey(0)))
    assert c1 == c2
    assert c1.get("random_split", 0) >= 1
    assert c1.get("random_bits", 0) >= 2


def _smoke_scenario(steps=8):
    from repro.campaign import engine
    from repro.campaign.run import CAMPAIGNS
    scens = [s for s in CAMPAIGNS["smoke"](1, steps)
             if s.defense == "safeguard_double"]
    return engine.group_scenarios(scens)[0][0]


def test_recompilation_detector_catches_injected_knob_leak():
    """Acceptance: bake a knob value into a copy of the trial fn (the
    exact regression class the engine's knobs-as-lanes design forbids)
    and assert the invariance probe flags it."""
    from repro.campaign import engine

    s = _smoke_scenario()

    def leaky_make(scenario):
        trial = engine.make_trial_fn(scenario)
        baked = float(scenario.threshold_floor)   # leaks into structure

        def mutated(knobs):
            k = dict(knobs)
            k["threshold_floor"] = baked
            return trial(k)
        return mutated

    caught = jaxpr_passes.check_knob_invariance(
        s, "mutated-smoke", make_fn=leaky_make,
        knobs=["threshold_floor"])
    assert [v.rule for v in caught] == ["knob-structure"]
    assert "threshold_floor" in caught[0].message


def test_clean_trial_is_knob_invariant():
    s = _smoke_scenario()
    assert not jaxpr_passes.check_knob_invariance(
        s, "clean-smoke", knobs=["threshold_floor", "attack_scale"])


def test_baselines_pinned_for_committed_programs():
    """The committed baseline files cover every current campaign
    program label (regenerating is explicit: --update-baselines) and
    record the jax version they were generated under."""
    hashes_doc = json.loads(jaxpr_passes.JAXPR_BASELINE.read_text())
    rng_doc = json.loads(jaxpr_passes.RNG_BASELINE.read_text())
    assert hashes_doc["jax"] == rng_doc["jax"]
    hashes, rng = hashes_doc["programs"], rng_doc["programs"]
    assert set(hashes) == set(rng)
    assert len(hashes) > 50
    for campaign in jaxpr_passes.CAMPAIGN_NAMES[:4]:
        assert any(lab.startswith(campaign + "/") for lab in hashes), \
            campaign


def test_baseline_version_skew_collapses_to_one_report(tmp_path):
    """Hash diffs under a different jax version are version skew, not a
    repo regression: they collapse to a single 'rerun under jax X'
    violation instead of a per-program avalanche."""
    path = tmp_path / "jaxpr_hashes.json"
    pinned = {"p1": "aaaa", "p2": "bbbb", "p3": "cccc"}
    current = {"p1": "aaaa", "p2": "beef", "p3": "feed"}

    path.write_text(json.dumps({"jax": "0.0.1", "programs": pinned}))
    skewed = jaxpr_passes._diff_baseline(path, current, "jaxpr-drift", "h")
    assert len(skewed) == 1
    assert "jax 0.0.1" in skewed[0].message

    # same diffs under the SAME version: real drift, reported per program
    path.write_text(json.dumps(
        {"jax": jaxpr_passes._jax_version(), "programs": pinned}))
    real = jaxpr_passes._diff_baseline(path, current, "jaxpr-drift", "h")
    assert len(real) == 2
    assert all(v.rule == "jaxpr-drift" for v in real)

    # version skew with NO diffs stays silent (pretty-printing stable)
    path.write_text(json.dumps({"jax": "0.0.1", "programs": current}))
    assert jaxpr_passes._diff_baseline(path, current, "jaxpr-drift", "h") \
        == []


def test_violation_format():
    v = Violation("f64", "src/x.py", 3, "msg", col=7)
    assert v.format() == "src/x.py:3:7: f64 msg"
