"""Live telemetry — layer 4 of the flight recorder (DESIGN.md §17).

The load-bearing invariant: tapping is OBSERVATION ONLY.  A tapped
trial consumes the same rng stream and produces the same accuracy as
the untapped one, on every campaign program family (iid, hetero,
saddle); integer/boolean traces are bit-identical everywhere.  Float
traces are bit-identical on the programs tested here except where XLA
re-fuses shared subexpressions across the nested-scan boundary — those
stay within 1 ULP and are locked with a tight allclose (the caveat is
documented in DESIGN.md §17).

Also covered: the LiveCollector host side (ring, heartbeat files,
step_rate, lane mapping, never-raise), the alert-rule catalog on
synthetic streams (each rule fires exactly on its trigger and never on
a clean stream), the Chrome-trace schema contract, and the regression
gate's offline comparison path."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.campaign import engine
from repro.campaign.run import CAMPAIGNS
from repro.obs import alerts as alerts_lib
from repro.obs import live as live_lib
from repro.obs import perfetto
from repro.obs import schema as obs_schema
from repro.obs.profile import PhaseTimer

STEPS = 40
TAP_EVERY = 10


class _Sink:
    """Bare-list tap target (the io_callback side of LiveCollector)."""

    def __init__(self):
        self.payloads = []

    def __call__(self, payload):
        self.payloads.append({k: np.asarray(v) for k, v in
                              payload.items()})


def _first_group(campaign, lanes=2):
    scenarios = CAMPAIGNS[campaign](1, STEPS)
    return engine.group_scenarios(scenarios)[0][:lanes]


# ------------------------------------------------ tapped == untapped


@pytest.mark.parametrize("campaign", ["live", "hetero", "saddle"])
def test_tapped_trial_is_untapped_trial(campaign):
    group = _first_group(campaign)
    base = engine.run_group(group)
    sink = _Sink()
    tapped = engine.run_group(group, tap=sink, tap_every=TAP_EVERY)

    assert len(sink.payloads) == (STEPS // TAP_EVERY) * len(group)
    for lane, (b, t) in enumerate(zip(base, tapped)):
        # the acceptance invariant: accuracy diff is exactly 0.0
        assert float(b["acc"]) == float(t["acc"]), f"lane {lane}"
        for key in ("caught_byz", "evicted_honest"):
            if key in b:
                assert int(b[key]) == int(t[key])
        assert set(b["traces"]) == set(t["traces"])
        for k in sorted(b["traces"]):
            a0 = np.asarray(b["traces"][k])
            a1 = np.asarray(t["traces"][k])
            if a0.dtype.kind in "ib":
                np.testing.assert_array_equal(a0, a1, err_msg=k)
            else:
                # float traces: exact up to XLA's nested-scan re-fusion
                # (<= 1 ULP on the affected programs — DESIGN.md §17)
                np.testing.assert_allclose(a0, a1, rtol=3e-7, atol=1e-30,
                                           err_msg=k)


def test_tap_payloads_are_schema_typed_with_lane_identity():
    group = _first_group("live")
    sink = _Sink()
    engine.run_group(group, tap=sink, tap_every=TAP_EVERY)
    lanes_seen = set()
    for p in sink.payloads:
        assert set(p) <= set(obs_schema.TAP)
        for k, v in p.items():
            assert v.dtype == np.dtype(obs_schema.TAP[k].dtype), k
            assert v.ndim == 0, f"{k} must arrive unbatched"
        lanes_seen.add(int(p["lane"]))
    assert lanes_seen == set(range(len(group)))
    steps = sorted({int(p["step"]) for p in sink.payloads})
    assert steps == list(range(TAP_EVERY, STEPS + 1, TAP_EVERY))


def test_tapped_rng_signature_is_unchanged():
    """The tap consumes zero rng: primitive-level rng counts of the
    tapped jaxpr equal the untapped one (the lint tier-2 signature)."""
    from repro.lint import jaxpr_passes
    rep = _first_group("live", lanes=1)[0]
    knobs = {k: v[0] for k, v in engine.stack_knobs([rep]).items()}
    plain = jax.make_jaxpr(engine.make_trial_fn(rep))(knobs)
    tapped = jax.make_jaxpr(
        engine.make_trial_fn(rep, tap=lambda p: None,
                             tap_every=TAP_EVERY))(knobs)
    assert jaxpr_passes.rng_counts(plain) == jaxpr_passes.rng_counts(
        tapped)
    assert jaxpr_passes.rng_counts(plain)          # non-trivial program


def test_untapped_program_structure_is_byte_identical():
    """tap_every=0 must be the pre-live-layer program, byte for byte
    (committed tier-2 jaxpr baselines depend on it)."""
    from repro.lint import jaxpr_passes
    rep = _first_group("live", lanes=1)[0]
    knobs = {k: v[0] for k, v in engine.stack_knobs([rep]).items()}
    a = jax.make_jaxpr(engine.make_trial_fn(rep))(knobs)
    b = jax.make_jaxpr(engine.make_trial_fn(rep, tap=lambda p: None,
                                            tap_every=0))(knobs)
    assert str(a) == str(b)


# ------------------------------------------------ scan_trial plumbing


def test_scan_trial_tap_validation():
    from repro.train import scan_trial

    def step(st, batch):
        return st + 1, {"loss": jnp.float32(batch)}

    with pytest.raises(ValueError, match="needs a host `tap`"):
        scan_trial(step, jnp.int32(0), batch_fn=lambda t: t, steps=40,
                   tap_every=10)
    with pytest.raises(ValueError, match="multiple of"):
        scan_trial(step, jnp.int32(0), batch_fn=lambda t: t, steps=40,
                   tap_every=7, tap=lambda p: None)


def test_fit_tap_every_snaps_to_divisor():
    assert engine.fit_tap_every(40, 50) == 40
    assert engine.fit_tap_every(40, 16) == 10
    assert engine.fit_tap_every(40, 10) == 10
    assert engine.fit_tap_every(41, 10) == 1
    assert engine.fit_tap_every(40, 0) == 0
    assert engine.fit_tap_every(40, 1) == 1


def test_validate_tap_rejects_unknown_key():
    with pytest.raises(obs_schema.SchemaError, match="not_a_tap_key"):
        obs_schema.validate_tap({"step": jnp.int32(1),
                                 "not_a_tap_key": jnp.float32(0)})


# ------------------------------------------------ LiveCollector host side


def _beat(step, **kw):
    b = {"step": step, "loss": 1.0, "lane": 0}
    b.update(kw)
    return b


def test_collector_rings_files_and_rates(tmp_path):
    ticks = iter(np.arange(0.0, 100.0, 0.5))
    col = live_lib.LiveCollector(
        name="t", lane_ids=["cellA", "cellB"],
        heartbeat_dir=tmp_path, maxlen=3, clock=lambda: next(ticks))
    # t0 consumed one tick; each tap consumes the next (0.5s apart)
    col.tap({"step": np.int32(10), "loss": np.float32(1.0),
             "lane": np.int32(0)})
    col.tap({"step": np.int32(10), "loss": np.float32(2.0),
             "lane": np.int32(1)})
    col.tap({"step": np.int32(20), "loss": np.float32(0.5),
             "lane": np.int32(0)})
    col.close()
    assert col.dropped == 0
    a = col.beats("cellA")
    assert [b["step"] for b in a] == [10, 20]
    assert a[0].get("step_rate") is None       # no previous beat yet
    # 10 steps in 2 ticks of 0.5s => 10/s
    assert a[1]["step_rate"] == pytest.approx(10.0)
    # files: one JSONL per cell, sorted keys, typed scalars
    streams = live_lib.load_heartbeats(tmp_path)
    assert sorted(streams) == ["cellA", "cellB"]
    assert [b["loss"] for b in streams["cellA"]] == [1.0, 0.5]
    line = (tmp_path / "cellA.jsonl").read_text().splitlines()[0]
    assert json.loads(line)["cell"] == "cellA"
    assert isinstance(json.loads(line)["step"], int)


def test_collector_ring_is_bounded_and_never_raises(tmp_path):
    col = live_lib.LiveCollector(name="solo", maxlen=4)
    for i in range(10):
        col.tap({"step": np.int32(i), "loss": np.float32(i)})
    assert len(col.beats()) == 4                     # ring bounded
    assert [b["step"] for b in col.beats()] == [6, 7, 8, 9]
    assert all(b["cell"] == "solo" for b in col.beats())
    # a poisoned payload is dropped, not raised into the device program
    col.tap({"step": "not-a-number"})
    assert col.dropped == 1
    col.tap({"step": np.int32(10), "loss": np.float32(0)})
    assert [b["step"] for b in col.beats()][-1] == 10


def test_collector_set_lanes_and_unknown_lane():
    col = live_lib.LiveCollector(name="c", lane_ids=["x"])
    col.tap({"step": np.int32(1), "lane": np.int32(5)})
    assert col.beats()[0]["cell"] == "lane5"         # out of range
    col.set_lanes(["p", "q"])
    col.tap({"step": np.int32(1), "lane": np.int32(1)})
    assert col.beats()[-1]["cell"] == "q"


def test_collector_appends_on_resume(tmp_path):
    """Reopening a collector over the same heartbeat dir appends; it
    never truncates (campaign --resume leaves finished cells' files
    byte-identical because skipped cells emit no beats)."""
    with live_lib.LiveCollector(name="r", lane_ids=["c"],
                                heartbeat_dir=tmp_path) as col:
        col.tap({"step": np.int32(1), "lane": np.int32(0)})
    first = (tmp_path / "c.jsonl").read_bytes()
    # resumed run, cell already complete: no beats for it => untouched
    with live_lib.LiveCollector(name="r", lane_ids=["c"],
                                heartbeat_dir=tmp_path):
        pass
    assert (tmp_path / "c.jsonl").read_bytes() == first
    # resumed run with new beats: strictly appended
    with live_lib.LiveCollector(name="r", lane_ids=["c"],
                                heartbeat_dir=tmp_path) as col:
        col.tap({"step": np.int32(2), "lane": np.int32(0)})
    data = (tmp_path / "c.jsonl").read_bytes()
    assert data.startswith(first) and len(data) > len(first)


# ------------------------------------------------ Trainer parity


@pytest.fixture(scope="module")
def trainer_setup():
    from repro.configs.base import TrainConfig
    from repro.core import attacks as atk_lib
    from repro.core import defenses as dfn_lib
    from repro.data import tasks
    from repro.optim import make_optimizer
    from repro.train import init_train_state, make_train_step

    m, nbyz = 6, 2
    byz = jnp.arange(m) < nbyz
    task = tasks.make_teacher_task(d_in=8, d_hidden=8, n_classes=4)
    opt = make_optimizer(TrainConfig(lr=0.1))
    defense = dfn_lib.make_registry(m, nbyz, T0=5, T1=15)[
        "safeguard_double"]
    attack = atk_lib.make_registry()["variance"]

    def fresh():
        params = tasks.student_init(task)
        state = init_train_state(params, opt, defense=defense,
                                 attack=attack)
        step = make_train_step(tasks.mlp_loss, opt, byz_mask=byz,
                               defense=defense, attack=attack, jit=False)
        it = tasks.teacher_batches(task, 48, m=m)
        return state, jax.jit(step), it

    return fresh


def test_trainer_history_identical_with_collector(trainer_setup):
    """The collector observes the log boundary; scalar history is
    bit-identical with and without it."""
    from repro.train import Trainer

    state, step, it = trainer_setup()
    plain = Trainer(state, step, it, log_every=2, name="p")
    h0 = plain.run(6, verbose=False)

    state, step, it = trainer_setup()
    col = live_lib.LiveCollector(name="w")
    watched = Trainer(state, step, it, log_every=2, name="w",
                      collector=col)
    h1 = watched.run(6, verbose=False)

    assert len(h0) == len(h1) == 3
    for r0, r1 in zip(h0, h1):
        assert set(r0) == set(r1)
        for k in r0:
            if k == "wall_s":
                continue                    # host wall-clock, not data
            assert r0[k] == r1[k], k
    beats = col.beats()
    assert [b["step"] for b in beats] == [r["step"] for r in h1]
    assert all(set(b) - {"cell", "t_wall", "step_rate"}
               <= set(obs_schema.TAP) for b in beats)


# ------------------------------------------------ alert rules


def _clean_stream(n=8):
    return [{"step": 10 * (i + 1), "loss": 1.0 - 0.05 * i,
             "honest_loss": 1.0 - 0.05 * i, "n_good": 10.0,
             "caught_byz": 0, "evicted_honest": 0,
             "threshold_B": 1.0 + 0.01 * i, "threshold_A": 2.0,
             "escape_on": 0.0, "min_eig_proxy": 0.1,
             "step_rate": 100.0, "cell": "clean"}
            for i in range(n)]


def test_clean_stream_raises_no_alerts():
    assert alerts_lib.extract_alerts(_clean_stream(), cell="clean") == []


def test_nan_guard_fires_on_first_nonfinite_beat():
    beats = _clean_stream()
    beats[3]["loss"] = float("nan")
    beats[5]["threshold_B"] = float("inf")
    out = alerts_lib.extract_alerts(beats, cell="c")
    nan = [a for a in out if a.rule == "nan_guard"]
    assert len(nan) == 1                        # first poison only
    assert nan[0].severity == alerts_lib.CRITICAL
    assert nan[0].step == beats[3]["step"]
    assert "loss" in nan[0].message


def test_eviction_storm_counts_pre_heartbeat_evictions():
    beats = _clean_stream()
    for b in beats:                              # storm before beat 1
        b["caught_byz"], b["n_good"] = 3, 7.0
    out = [a for a in alerts_lib.extract_alerts(beats, cell="c")
           if a.rule == "eviction_storm"]
    assert len(out) == 1 and out[0].step == beats[0]["step"]


def test_eviction_storm_gradual_eviction_is_quiet():
    beats = _clean_stream()
    for b in beats[4:]:                          # one slow eviction
        b["caught_byz"], b["n_good"] = 1, 9.0
    assert [a.rule for a in alerts_lib.extract_alerts(beats, cell="c")
            ] == []


def test_eviction_storm_rearms_after_restore():
    beats = _clean_stream(12)
    for b in beats[2:5]:                         # first storm
        b["caught_byz"], b["n_good"] = 2, 8.0
    for b in beats[5:8]:                         # periodic reset restores
        b["caught_byz"], b["n_good"] = 0, 10.0
    for b in beats[8:]:                          # second storm
        b["caught_byz"], b["n_good"] = 2, 8.0
    storms = [a for a in alerts_lib.extract_alerts(beats, cell="c")
              if a.rule == "eviction_storm"]
    assert [a.step for a in storms] == [beats[2]["step"],
                                        beats[8]["step"]]


def test_threshold_runaway_fires_once_per_guard():
    beats = _clean_stream(10)
    for b in beats[5:]:
        b["threshold_B"] = 200.0                 # 50x the ~1.0 median
    out = [a for a in alerts_lib.extract_alerts(beats, cell="c")
           if a.rule == "threshold_runaway"]
    assert len(out) == 1
    assert out[0].step == beats[5]["step"]
    assert "threshold_B" in out[0].message


def test_stalled_escape_needs_persistent_negative_curvature():
    beats = _clean_stream(10)
    for b in beats[2:]:
        b["escape_on"], b["min_eig_proxy"] = 1.0, -0.05
    out = [a for a in alerts_lib.extract_alerts(beats, cell="c")
           if a.rule == "stalled_escape"]
    assert len(out) == 1
    assert out[0].step == beats[4]["step"]       # 3rd consecutive beat
    # a single blip does not fire
    beats = _clean_stream(10)
    beats[3]["escape_on"], beats[3]["min_eig_proxy"] = 1.0, -0.05
    assert not [a for a in alerts_lib.extract_alerts(beats, cell="c")
                if a.rule == "stalled_escape"]


def test_step_rate_collapse_fires_and_rearms():
    beats = _clean_stream(10)
    beats[5]["step_rate"] = 10.0                 # < 25% of median 100
    out = [a for a in alerts_lib.extract_alerts(beats, cell="c")
           if a.rule == "step_rate_collapse"]
    assert len(out) == 1 and out[0].step == beats[5]["step"]
    # rule disarms until the rate recovers: a sustained collapse is one
    # alert, a second independent collapse is a second alert
    beats[6]["step_rate"] = 9.0
    beats[8]["step_rate"] = 8.0                  # recovered at 7, re-fires
    out = [a for a in alerts_lib.extract_alerts(beats, cell="c")
           if a.rule == "step_rate_collapse"]
    assert [a.step for a in out] == [beats[5]["step"], beats[8]["step"]]


def test_rules_disarm_without_their_keys():
    """A program that taps only loss arms nothing but nan_guard."""
    beats = [{"step": 10 * i, "loss": 1.0} for i in range(8)]
    assert alerts_lib.extract_alerts(beats, cell="c") == []


# ------------------------------------------------ perfetto schema


def test_chrome_trace_schema_roundtrip():
    pt = PhaseTimer()
    with pt.phase("outer"):
        with pt.phase("inner"):
            pass
    rec = {"lower_s": 0.1, "compile_s": 0.2, "execute_s": 0.05,
           "hlo": {"collective_bytes": {"all-reduce": 128.0},
                   "collective_counts": {"all-reduce": 2}}}
    events = [perfetto.meta_event("process_name", "prog", pid=1)]
    events += perfetto.profile_events(rec, pid=1, label="prog")
    events += perfetto.timer_events(pt, pid=0)
    trace = perfetto.chrome_trace(events)
    out = perfetto.validate_chrome_trace(json.loads(json.dumps(trace)))
    phases = {e["ph"] for e in out}
    assert {"X", "C", "M"} <= phases
    spans = [e for e in out if e["ph"] == "X"]
    assert {"lower", "compile", "execute", "outer", "inner"} <= {
        e["name"] for e in spans}
    assert all(e["dur"] >= 0 for e in spans)
    # the nested PhaseTimer span is contained in its parent
    named = {e["name"]: e for e in spans}
    assert named["inner"]["ts"] >= named["outer"]["ts"]
    counters = [e for e in out if e["ph"] == "C"]
    assert counters and all(isinstance(e["args"], dict)
                            for e in counters)


@pytest.mark.parametrize("bad,msg", [
    ({"traceEvents": "nope"}, "must be a list"),
    ({"traceEvents": [{"ph": "X", "pid": 0}]}, "missing 'name'"),
    ({"traceEvents": [{"name": "a", "ph": "Z", "pid": 0, "ts": 0}]},
     "unknown phase"),
    ({"traceEvents": [{"name": "a", "ph": "X", "pid": 0, "ts": 0}]},
     "dur"),
    ({"traceEvents": [{"name": "a", "ph": "C", "pid": 0, "ts": 0}]},
     "args"),
    ({"traceEvents": [{"name": "a", "ph": "X", "pid": 0, "dur": 1}]},
     "'ts' must be a number"),
])
def test_chrome_trace_schema_rejects_malformed(bad, msg):
    with pytest.raises(ValueError, match=msg):
        perfetto.validate_chrome_trace(bad)


def test_zero_collectives_emit_no_counter_track():
    rec = {"lower_s": 0.1, "compile_s": 0.2, "execute_s": 0.05,
           "hlo": {"collective_bytes": {"all-reduce": 0.0},
                   "collective_counts": {"all-reduce": 0}}}
    events = perfetto.profile_events(rec)
    assert not [e for e in events if e["ph"] == "C"]


# ------------------------------------------------ regression gate


def test_regress_offline_pass_and_fail(tmp_path):
    from benchmarks import regress

    base = {"claim_holds": True, "taps_fired_ok": True,
            "tap50_overhead_frac": 0.001, "tap10_overhead_frac": 0.01}
    (tmp_path / "base").mkdir()
    (tmp_path / "fresh").mkdir()
    suite = regress.SUITES["live"]
    with open(tmp_path / "base" / suite.baseline, "w") as f:
        json.dump(base, f)
    with open(tmp_path / "fresh" / suite.baseline, "w") as f:
        json.dump(base, f)
    assert regress.run(only=["live"], against=str(tmp_path / "fresh"),
                       baseline_dir=tmp_path / "base") == []

    bad = dict(base, claim_holds=False, tap50_overhead_frac=0.5)
    with open(tmp_path / "fresh" / suite.baseline, "w") as f:
        json.dump(bad, f)
    failures = regress.run(only=["live"],
                           against=str(tmp_path / "fresh"),
                           baseline_dir=tmp_path / "base")
    assert len(failures) == 2
    assert any("claim_holds" in f for f in failures)
    assert any("tap50_overhead_frac" in f for f in failures)


def test_regress_committed_baselines_are_self_consistent():
    """The committed BENCH files must pass their own gate (the --check
    path re-measures; here we verify the committed trajectory itself
    honors every ceiling/floor/bool)."""
    from benchmarks import regress

    root = Path(regress.REPO_ROOT)
    for name, suite in regress.SUITES.items():
        with open(root / suite.baseline) as f:
            base = json.load(f)
        assert regress.compare(base, base, suite.checks, name=name) == []


# ------------------------------------------------ CLI gate


def test_alerts_cli_expectations(tmp_path):
    live = tmp_path / "camp" / "live"
    live.mkdir(parents=True)
    clean = _clean_stream()
    stormy = _clean_stream()
    for b in stormy:
        b["caught_byz"], b["n_good"] = 4, 6.0
    for cell, beats in (("none-safeguard", clean),
                        ("variance-safeguard", stormy)):
        with open(live / f"{cell}.jsonl", "w") as f:
            for b in beats:
                f.write(json.dumps(dict(b, cell=cell)) + "\n")
    argv = ["alerts", "--root", str(tmp_path), "--campaign", "camp"]
    assert live_lib.main(argv + ["--expect-clean", "none-",
                                 "--expect",
                                 "eviction_storm:variance-"]) == 0
    assert live_lib.main(argv + ["--expect-clean", "variance-"]) == 1
    assert live_lib.main(argv + ["--expect",
                                 "nan_guard:variance-"]) == 1
    assert live_lib.main(argv + ["--expect",
                                 "eviction_storm:nonexistent"]) == 1


def test_tail_once_renders_latest_beats(tmp_path, capsys):
    live = tmp_path / "camp" / "live"
    live.mkdir(parents=True)
    with open(live / "cellZ.jsonl", "w") as f:
        for b in _clean_stream(3):
            f.write(json.dumps(dict(b, cell="cellZ")) + "\n")
    assert live_lib.main(["tail", "--root", str(tmp_path),
                          "--campaign", "camp", "--once"]) == 0
    out = capsys.readouterr().out
    assert "[cellZ]" in out and "step     30" in out
