"""Distributed semantics: run a small 8-device host-platform mesh in a
subprocess (device count must be fixed before jax initializes, so it can't
run in the main pytest process) and check that the sharded safeguard step
produces bit-identical decisions and numerically identical aggregates to
the single-device run."""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import SafeguardConfig, init_state, safeguard_step
from repro.core import tree_utils as tu

m, d1, d2 = 4, 16, 6
cfg = SafeguardConfig(m=m, T0=5, T1=10, threshold_floor=0.2)
key = jax.random.PRNGKey(0)
params = {"w": jnp.zeros((d1, d2)), "b": jnp.zeros((d2,))}

def grads_at(t):
    k = jax.random.fold_in(key, t)
    g = {"w": 1.0 + 0.05 * jax.random.normal(k, (m, d1, d2)),
         "b": 1.0 + 0.05 * jax.random.normal(jax.random.fold_in(k, 1),
                                             (m, d2))}
    # worker 0 is byzantine: sign flip
    return jax.tree.map(lambda x: x.at[0].set(-x[0]), g)

# ---- single device reference -------------------------------------------
st = init_state(cfg, params)
for t in range(12):
    st, agg_ref, info_ref = safeguard_step(st, grads_at(t), cfg)
good_ref = np.asarray(st.good)

# ---- sharded (data=4 workers, model=2) ----------------------------------
from repro.launch.mesh import auto_axis_types
mesh = jax.make_mesh((4, 2), ("data", "model"), **auto_axis_types(2))
gspec = {"w": NamedSharding(mesh, P("data", None, "model")),
         "b": NamedSharding(mesh, P("data", "model"))}
step = jax.jit(lambda s, g: safeguard_step(s, g, cfg))
with mesh:
    st2 = init_state(cfg, params)
    for t in range(12):
        g = jax.tree.map(lambda x, s: jax.device_put(x, s), grads_at(t),
                         gspec)
        st2, agg, info = step(st2, g)
good_shard = np.asarray(st2.good)

assert (good_ref == good_shard).all(), (good_ref, good_shard)
assert not good_ref[0] and good_ref[1:].all()
np.testing.assert_allclose(np.asarray(agg["w"]), np.asarray(agg_ref["w"]),
                           rtol=1e-5, atol=1e-5)

# gram under sharding == gram locally
g = grads_at(99)
gs = jax.tree.map(lambda x, s: jax.device_put(x, s), g, gspec)
with mesh:
    gram_sharded = np.asarray(jax.jit(tu.tree_gram)(gs))
gram_local = np.asarray(tu.tree_gram(g))
np.testing.assert_allclose(gram_sharded, gram_local, rtol=1e-4, atol=1e-4)
print("DISTRIBUTED_OK")
"""


def test_sharded_safeguard_matches_local():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "DISTRIBUTED_OK" in out.stdout, (out.stdout, out.stderr)


@pytest.mark.slow
def test_dryrun_single_pair_end_to_end():
    """Full dry-run driver on the smallest pair (its own process — it
    forces 512 host devices)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "mamba2-130m", "--shape", "decode_32k", "--out",
         "/tmp/dryrun_test"],
        env=env, capture_output=True, text=True, timeout=1200,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "all dry runs OK" in out.stdout, (out.stdout[-2000:],
                                             out.stderr[-2000:])
