"""Pallas kernel validation (interpret=True on CPU): shape/dtype sweeps
asserting allclose against each ``ref.py`` pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.safeguard_filter import pairwise_sqdist
from repro.kernels.safeguard_filter import ref as sf_ref
from repro.kernels.robust_agg import coord_median, trimmed_mean
from repro.kernels.robust_agg import ref as ra_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention import ref as fa_ref


# --------------------------------------------------------------------------
# safeguard_filter
# --------------------------------------------------------------------------

@pytest.mark.parametrize("m,d", [(4, 128), (10, 1000), (16, 4096),
                                 (7, 513), (32, 2048), (33, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_sqdist_sweep(m, d, dtype, rng):
    a = jax.random.normal(rng, (m, d), dtype)
    out = pairwise_sqdist(a)
    want = sf_ref.pairwise_sqdist(a)
    tol = 1e-3 * d if dtype == jnp.bfloat16 else 1e-4 * d
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=tol)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(jnp.diagonal(out)), 0.0,
                               atol=tol)


def test_pairwise_sqdist_symmetry(rng):
    a = jax.random.normal(rng, (12, 777))
    out = np.asarray(pairwise_sqdist(a))
    np.testing.assert_allclose(out, out.T, atol=1e-4)


# --------------------------------------------------------------------------
# robust_agg
# --------------------------------------------------------------------------

@pytest.mark.parametrize("m,d", [(5, 128), (10, 1000), (16, 4096),
                                 (9, 257), (8, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_coord_median_sweep(m, d, dtype, rng):
    g = jax.random.normal(rng, (m, d), dtype)
    out = coord_median(g)
    want = ra_ref.coord_median(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("m,d,trim", [(10, 512, 2), (16, 1000, 4),
                                      (7, 129, 1)])
def test_trimmed_mean_sweep(m, d, trim, rng):
    g = jax.random.normal(rng, (m, d))
    out = trimmed_mean(g, trim=trim)
    want = ra_ref.trimmed_mean(g, trim)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5)


def test_trimmed_mean_overtrim_raises(rng):
    with pytest.raises(ValueError):
        trimmed_mean(jax.random.normal(rng, (4, 128)), trim=2)


# --------------------------------------------------------------------------
# flash_attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,K,L,D,win,bq,bk", [
    (1, 4, 4, 256, 64, 0, 64, 64),     # MHA
    (2, 8, 2, 128, 64, 0, 64, 32),     # GQA
    (1, 4, 1, 256, 64, 96, 64, 64),    # MQA + sliding window
    (2, 2, 2, 200, 32, 0, 64, 64),     # padded sequence
    (1, 2, 2, 128, 128, 0, 128, 128),  # single block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, K, L, D, win, bq, bk, dtype, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, H, L, D), dtype)
    k = jax.random.normal(ks[1], (B, K, L, D), dtype)
    v = jax.random.normal(ks[2], (B, K, L, D), dtype)
    out = flash_attention(q, k, v, window=win, block_q=bq, block_k=bk)
    want = fa_ref.attention(q, k, v, window=win)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)
    assert out.dtype == dtype


def test_flash_attention_first_row_attends_self_only(rng):
    B, H, L, D = 1, 1, 128, 32
    q = jax.random.normal(rng, (B, H, L, D))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, H, L, D))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, H, L, D))
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(out[0, 0, 0]),
                               np.asarray(v[0, 0, 0]), rtol=1e-5)
