"""Flat-buffer safeguard engine (DESIGN.md §6) equivalence suite: the
flat engine must reproduce the stacked-pytree reference bit-for-bit in
its *decisions* (eviction masks, eviction times, medians) and match the
aggregate numerically, across mode x rule x reset-period x backend; plus
layout round-trips and the fused-kernel oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SafeguardConfig, init_state, safeguard_step
from repro.core import attacks as atk
from repro.core import safeguard as sg
from repro.kernels.safeguard_filter import fused_accumulate_sqdist
from repro.kernels.safeguard_filter import ref as sf_ref

M = 10
PARAMS = {"w": jnp.zeros((20, 5)), "b": jnp.zeros((5,)),
          "blocks": {"h": jnp.zeros((3, 4, 2))}}


def honest_grads(key, mu=1.0, sigma=0.05):
    ks = jax.random.split(key, len(jax.tree_util.tree_leaves(PARAMS)))
    ks = iter(list(ks))
    return jax.tree.map(
        lambda p: mu + sigma * jax.random.normal(next(ks), (M,) + p.shape),
        PARAMS)


def run(cfg, attack_fn, byz_mask, steps, seed=0):
    st = init_state(cfg, PARAMS)
    key = jax.random.PRNGKey(seed)
    astate = None
    step = jax.jit(lambda s, g: safeguard_step(s, g, cfg))
    agg = None
    for t in range(steps):
        key, k = jax.random.split(key)
        g = honest_grads(k)
        g, astate = attack_fn(g, byz_mask, astate, jnp.int32(t), k)
        st, agg, info = step(st, g)
    return st, agg, info


ENGINE_GRID = [("stacked", "pallas"), ("flat", "pallas"), ("flat", "xla"),
               ("flat", "pallas_fused")]


@pytest.mark.parametrize("mode", ["double", "single"])
@pytest.mark.parametrize("rule", ["empirical", "theoretical"])
def test_flat_matches_stacked_decisions(mode, rule):
    byz = jnp.arange(M) < 4
    kwargs = dict(m=M, T0=20, T1=60, mode=mode, rule=rule)
    if rule == "empirical":
        kwargs["threshold_floor"] = 0.5
    else:
        t0, t1 = SafeguardConfig.theoretical_thresholds(20, 60, M, V=0.2)
        kwargs.update(thresh0=t0, thresh1=t1)
    outs = {}
    for engine, backend in ENGINE_GRID:
        cfg = SafeguardConfig(engine=engine, backend=backend, **kwargs)
        st, agg, info = run(cfg, atk.attack_sign_flip, byz, 60)
        outs[(engine, backend)] = (st, agg, info)

    ref_st, ref_agg, ref_info = outs[("stacked", "pallas")]
    assert bool((~ref_st.good[:4]).all()), "attack must be caught"
    for key, (st, agg, info) in outs.items():
        np.testing.assert_array_equal(np.asarray(st.good),
                                      np.asarray(ref_st.good), err_msg=str(key))
        np.testing.assert_array_equal(np.asarray(st.evicted_at),
                                      np.asarray(ref_st.evicted_at),
                                      err_msg=str(key))
        assert int(info["med_B"]) == int(ref_info["med_B"]), key
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5), agg, ref_agg)


def test_flat_matches_stacked_with_reset_period():
    byz = jnp.arange(M) < 3
    attack = atk.make_burst(start=0, length=10, burst_scale=5.0)
    outs = {}
    for engine, backend in ENGINE_GRID:
        cfg = SafeguardConfig(m=M, T0=10, T1=20, threshold_floor=0.5,
                              reset_period=30, engine=engine,
                              backend=backend)
        st, _, _ = run(cfg, attack, byz, 35)
        outs[(engine, backend)] = st
    ref = outs[("stacked", "pallas")]
    assert bool(ref.good.all()), "reset must restore workers"
    for key, st in outs.items():
        np.testing.assert_array_equal(np.asarray(st.good),
                                      np.asarray(ref.good), err_msg=str(key))


@pytest.mark.parametrize("mag", [1e2, 1e4])
def test_sqdist_producers_clamp_at_zero(mag, rng):
    """NaN regression, producer level (deterministic twin of the
    hypothesis property test): near-duplicate large-magnitude rows push
    ``diag_i + diag_j - 2 G_ij`` into f32 cancellation; every sqdist
    producer must clamp at 0 so the filter's ``sqrt`` never sees a
    negative."""
    from repro.core import sketch as sk
    from repro.core import tree_utils as tu
    from repro.kernels.safeguard_filter import pairwise_sqdist
    m, d = 8, 256
    k1, k2 = jax.random.split(rng)
    rows = (mag * jax.random.normal(k1, (1, d))
            + 1e-6 * mag * jax.random.normal(k2, (m, d)))
    outs = {
        "pallas": pairwise_sqdist(rows),
        "ref": sf_ref.pairwise_sqdist(rows),
        "tree": tu.tree_pairwise_sqdist({"x": rows}),
        "fused": fused_accumulate_sqdist(
            jnp.zeros_like(rows), rows, 0, 1.0)[1],
        "sketch": sk.sketch_pairwise_sqdist(
            sk.sketch_tree({"x": rows}, k=128, reps=2)),
    }
    for name, sq in outs.items():
        sq = np.asarray(sq)
        assert np.isfinite(sq).all(), name
        assert (sq >= 0).all(), name
        assert np.isfinite(np.sqrt(sq)).all(), name


def test_near_duplicate_grads_no_nan_and_identical_decisions():
    """NaN regression through the full safeguard step: near-duplicate
    large-magnitude gradients drive the accumulator rows into the f32
    cancellation regime on every backend (and the sketched path); no
    distance may go NaN, no honest worker may be evicted, and all
    backends must agree on the decisions bit-for-bit.

    The threshold floor sits well above the f32 cancellation noise
    (distances here are ~pure rounding error, a few units at mu=1e3):
    pre-clamp, a negative sqdist turns into a NaN distance that compares
    False against ANY threshold and silently evicts — which is exactly
    what this test locks out."""
    byz = jnp.zeros((M,), bool)

    def near_dup_grads(key):
        ks = iter(list(jax.random.split(
            key, len(jax.tree_util.tree_leaves(PARAMS)))))
        return jax.tree.map(
            lambda p: 1e3 * (1.0 + 1e-6 * jax.random.normal(
                next(ks), (M,) + p.shape)), PARAMS)

    outs = {}
    grid = ENGINE_GRID + [("sketch", "pallas")]
    for engine, backend in grid:
        kwargs = dict(m=M, T0=20, T1=60, threshold_floor=100.0)
        if engine == "sketch":
            cfg = SafeguardConfig(use_sketch=True, sketch_k=512,
                                  sketch_reps=4, **kwargs)
        else:
            cfg = SafeguardConfig(engine=engine, backend=backend, **kwargs)
        st = init_state(cfg, PARAMS)
        key = jax.random.PRNGKey(0)
        step = jax.jit(lambda s, g, c=cfg: safeguard_step(s, g, c))
        for t in range(10):
            key, k = jax.random.split(key)
            st, agg, info = step(st, near_dup_grads(k))
            assert bool(jnp.isfinite(info["dist_to_med_B"]).all()), \
                (engine, backend, t)
            assert bool(jnp.isfinite(info["threshold_B"])), (engine, backend)
        assert bool(st.good.all()), (engine, backend)
        for leaf in jax.tree_util.tree_leaves(agg):
            assert bool(jnp.isfinite(leaf).all()), (engine, backend)
        outs[(engine, backend)] = np.asarray(st.good)
    ref = outs[("stacked", "pallas")]
    for k, good in outs.items():
        np.testing.assert_array_equal(good, ref, err_msg=str(k))


def test_flat_accumulator_equals_stacked_accumulator():
    """The buffer itself (not just decisions) matches: unflattening the
    flat accumulator row reproduces the stacked accumulator leaf."""
    byz = jnp.zeros((M,), bool)
    cfg_f = SafeguardConfig(m=M, T0=50, T1=100, threshold_floor=0.5)
    cfg_s = SafeguardConfig(m=M, T0=50, T1=100, threshold_floor=0.5,
                            engine="stacked")
    st_f, _, _ = run(cfg_f, atk.attack_none, byz, 7)
    st_s, _, _ = run(cfg_s, atk.attack_none, byz, 7)
    for i in (0, M - 1):
        row = sg.unflatten_row(st_f.B[i], st_f.layout)
        stacked_i = jax.tree.map(lambda l: l[i], st_s.B)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5), row, stacked_i)


def test_sketched_state_unaffected_by_engine_flag():
    """use_sketch wins over the engine choice and carries no layout."""
    byz = jnp.arange(M) < 4
    goods = []
    for engine in ("flat", "stacked"):
        cfg = SafeguardConfig(m=M, T0=20, T1=60, threshold_floor=0.5,
                              use_sketch=True, sketch_k=512, sketch_reps=4,
                              engine=engine)
        st, _, _ = run(cfg, atk.attack_sign_flip, byz, 60)
        assert st.layout is None
        assert st.B.shape == (M, 4 * 512)
        goods.append(np.asarray(st.good))
    np.testing.assert_array_equal(goods[0], goods[1])


def test_layout_static_and_round_trip():
    lay = sg.make_layout(PARAMS)
    assert lay.d == sum(l.size for l in jax.tree_util.tree_leaves(PARAMS))
    assert lay.d_padded % 128 == 0 and lay.d_padded >= lay.d
    assert hash(lay) == hash(sg.make_layout(PARAMS))   # jit-cache friendly
    g = honest_grads(jax.random.PRNGKey(3))
    flat = sg.flatten_stacked(g, lay)
    assert flat.shape == (M, lay.d_padded)
    back = sg.unflatten_row(flat[4], lay)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b[4]), atol=1e-6), back, g)


@pytest.mark.parametrize("m,d", [(10, 777), (8, 1024), (3, 50)])
@pytest.mark.parametrize("reset", [0, 1])
def test_fused_kernel_matches_oracle(m, d, reset, rng):
    k1, k2 = jax.random.split(rng)
    acc = jax.random.normal(k1, (m, d))
    g = jax.random.normal(k2, (m, d))
    new, sq = fused_accumulate_sqdist(acc, g, reset, 0.125)
    ref_new, ref_sq = sf_ref.fused_accumulate_sqdist(acc, g, reset, 0.125)
    np.testing.assert_allclose(np.asarray(new), np.asarray(ref_new),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(sq), np.asarray(ref_sq),
                               atol=1e-3 * max(d, 1))


def test_fused_kernel_reset_zeroes_nonfinite_accumulator(rng):
    """The window reset must be a select, not multiply-by-(1-reset): a
    Byzantine inf/NaN in the old accumulator has to vanish at the reset
    (inf * 0 = NaN would poison distances forever)."""
    acc = jnp.ones((8, 256)).at[2].set(jnp.inf).at[3].set(jnp.nan)
    g = jnp.ones((8, 256))
    new, sq = fused_accumulate_sqdist(acc, g, 1, 0.5)
    ref_new, ref_sq = sf_ref.fused_accumulate_sqdist(acc, g, 1, 0.5)
    assert bool(jnp.isfinite(new).all()) and bool(jnp.isfinite(sq).all())
    np.testing.assert_allclose(np.asarray(new), np.asarray(ref_new))
    np.testing.assert_allclose(np.asarray(sq), np.asarray(ref_sq),
                               atol=1e-3)


def test_fused_kernel_explicit_block_not_dividing(rng):
    """An explicit block_d that does not divide the lane-padded d must be
    handled by padding, not an assert."""
    k1, k2 = jax.random.split(rng)
    acc = jax.random.normal(k1, (8, 1280))
    g = jax.random.normal(k2, (8, 1280))
    new, sq = fused_accumulate_sqdist(acc, g, 0, 0.25, block_d=512)
    ref_new, ref_sq = sf_ref.fused_accumulate_sqdist(acc, g, 0, 0.25)
    np.testing.assert_allclose(np.asarray(new), np.asarray(ref_new),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(sq), np.asarray(ref_sq),
                               atol=1.3)


def test_flat_state_shapes_and_dtype():
    cfg = SafeguardConfig(m=M, T0=20, T1=60, threshold_floor=0.5,
                          acc_dtype=jnp.bfloat16)
    st = init_state(cfg, PARAMS)
    assert st.B.shape == (M, st.layout.d_padded)
    assert st.B.dtype == jnp.bfloat16
    # bf16 accumulators fall back to the XLA distance path and still run
    g = honest_grads(jax.random.PRNGKey(0))
    st2, _, _ = jax.jit(lambda s, gr: safeguard_step(s, gr, cfg))(st, g)
    assert st2.B.dtype == jnp.bfloat16
