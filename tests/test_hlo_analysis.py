"""Loop-aware HLO cost analysis: parser unit tests on a synthetic module
plus an end-to-end check that scanned-loop FLOPs are multiplied by the
trip count (single-device CPU compile — no forced device count needed)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H

_SYNTHETIC = """\
HloModule test

%body.1 (p.1: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p.1 = (s32[], f32[8,8]) parameter(0)
  %gte.0 = s32[] get-tuple-element(%p.1), index=0
  %gte.1 = f32[8,8] get-tuple-element(%p.1), index=1
  %d.1 = f32[8,8] dot(%gte.1, %gte.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %next = s32[] add(%gte.0, %one)
  ROOT %t.1 = (s32[], f32[8,8]) tuple(%next, %d.1)
}

%cond.1 (p.2: (s32[], f32[8,8])) -> pred[] {
  %p.2 = (s32[], f32[8,8]) parameter(0)
  %gte.2 = s32[] get-tuple-element(%p.2), index=0
  %lim = s32[] constant(7)
  ROOT %cmp = pred[] compare(%gte.2, %lim), direction=LT
}

ENTRY %main.1 (a.1: f32[8,8]) -> f32[8,8] {
  %a.1 = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %a.1)
  %w.1 = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1
  %ar.1 = f32[8,8] all-reduce(%a.1), channel_id=1, to_apply=%body.1
  ROOT %out = f32[8,8] get-tuple-element(%w.1), index=1
}
"""


def test_synthetic_module_trip_count_and_flops():
    res = H.analyze_hlo(_SYNTHETIC)
    # dot: 2 * 8*8 * 8 = 1024 flops, executed 7 times (constant(7))
    assert res["flops"] == pytest.approx(7 * 1024)
    # one all-reduce of 8*8*4 bytes at multiplier 1
    assert res["collective_bytes"]["all-reduce"] == 256
    assert res["collective_counts"]["all-reduce"] == 1


def test_parse_module_finds_computations():
    comps, entry = H.parse_module(_SYNTHETIC)
    assert entry == "%main.1"
    assert "%body.1" in comps and "%cond.1" in comps
    assert H._trip_count(comps["%cond.1"], comps) == 7


def test_real_scan_flops_scale_with_trip_count():
    w = jnp.ones((16, 16), jnp.float32)

    def make(n):
        def f(x):
            def body(c, _):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, None, length=n)
            return out
        txt = jax.jit(f).lower(jnp.ones((16, 16))).compile().as_text()
        return H.analyze_hlo(txt)["flops"]

    f4, f8 = make(4), make(8)
    assert f4 > 0
    assert f8 == pytest.approx(2 * f4, rel=0.05)


def test_shape_bytes():
    b, shapes = H._shape_info("(f32[2,3]{1,0}, bf16[4])")
    assert b == 2 * 3 * 4 + 4 * 2
    assert shapes[0] == ("f32", [2, 3])
