"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests and benches must see
the single real CPU device; only launch/dryrun.py forces 512 devices."""

import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tree_allclose(a, b, atol=1e-5, rtol=1e-5):
    ok = jax.tree.map(
        lambda x, y: jnp.allclose(jnp.asarray(x, jnp.float32),
                                  jnp.asarray(y, jnp.float32),
                                  atol=atol, rtol=rtol), a, b)
    return all(jax.tree_util.tree_leaves(ok))
