"""Layer-level oracles: SSD vs naive recurrence, RG-LRU vs sequential
loop, causal conv, RoPE properties, ring buffers, blocked flash vs dense."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L

f32 = jnp.float32


# --------------------------------------------------------------------------
# SSD
# --------------------------------------------------------------------------

def ssd_naive(x, dt, A, B, C):
    """Token-by-token linear recurrence oracle."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = np.repeat(np.asarray(B, np.float64), rep, axis=2)
    Ch = np.repeat(np.asarray(C, np.float64), rep, axis=2)
    xn = np.asarray(x, np.float64)
    dtn = np.asarray(dt, np.float64)
    An = np.asarray(A, np.float64)
    s = np.zeros((b, h, p, n))
    ys = np.zeros((b, l, h, p))
    for t in range(l):
        dA = np.exp(dtn[:, t] * An)                       # (b, h)
        s = s * dA[..., None, None] + np.einsum(
            "bhp,bhn->bhpn", xn[:, t] * dtn[:, t][..., None], Bh[:, t])
        ys[:, t] = np.einsum("bhpn,bhn->bhp", s, Ch[:, t])
    return ys, s


@pytest.mark.parametrize("chunk", [4, 8, 16])
@pytest.mark.parametrize("groups", [1, 2])
def test_ssd_scan_matches_naive(chunk, groups, rng):
    b, l, h, p, n = 2, 32, 4, 8, 16
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, groups, n))
    C = jax.random.normal(jax.random.fold_in(rng, 9), (b, l, groups, n))
    y, final = L.ssd_scan(x, dt, A, B, C, chunk)
    y_ref, s_ref = ssd_naive(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), s_ref, rtol=2e-4,
                               atol=2e-4)


def test_ssd_decode_continues_scan(rng):
    b, l, h, p, n = 1, 16, 2, 4, 8
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (b, l + 1, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l + 1, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l + 1, 1, n))
    C = jax.random.normal(jax.random.fold_in(rng, 7), (b, l + 1, 1, n))
    _, state = L.ssd_scan(x[:, :l], dt[:, :l], A, B[:, :l], C[:, :l], 8)
    new_state, y1 = L.ssd_decode_step(state, x[:, l], dt[:, l], A,
                                      B[:, l], C[:, l])
    y_full, s_full = ssd_naive(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), y_full[:, l], rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(new_state), s_full, rtol=1e-4,
                               atol=1e-4)


# --------------------------------------------------------------------------
# RG-LRU
# --------------------------------------------------------------------------

def test_rglru_scan_matches_loop(rng):
    b, l, d = 2, 24, 8
    ks = jax.random.split(rng, 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, l, d)))
    bb = jax.random.normal(ks[1], (b, l, d))
    h0 = jax.random.normal(ks[2], (b, d))
    h, h_last = L._rglru_scan(a, bb, h0)
    s = np.asarray(h0, np.float64)
    for t in range(l):
        s = np.asarray(a[:, t]) * s + np.asarray(bb[:, t])
        np.testing.assert_allclose(np.asarray(h[:, t]), s, rtol=1e-4,
                                   atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), s, rtol=1e-4, atol=1e-5)


def test_causal_conv_matches_manual(rng):
    b, l, c, w = 2, 10, 3, 4
    x = jax.random.normal(rng, (b, l, c))
    wgt = jax.random.normal(jax.random.fold_in(rng, 1), (w, c))
    bias = jax.random.normal(jax.random.fold_in(rng, 2), (c,))
    y, state = L._causal_conv(x, wgt, bias)
    xp = np.concatenate([np.zeros((b, w - 1, c)), np.asarray(x)], axis=1)
    for t in range(l):
        want = (xp[:, t:t + w] * np.asarray(wgt)[None]).sum(1) + \
            np.asarray(bias)
        np.testing.assert_allclose(np.asarray(y[:, t]), want, rtol=1e-4,
                                   atol=1e-5)
    np.testing.assert_allclose(np.asarray(state), xp[:, -(w - 1):],
                               atol=1e-6)


def test_causal_conv_decode_chaining(rng):
    b, l, c, w = 1, 8, 2, 4
    x = jax.random.normal(rng, (b, l, c))
    wgt = jax.random.normal(jax.random.fold_in(rng, 1), (w, c))
    bias = jnp.zeros((c,))
    y_full, _ = L._causal_conv(x, wgt, bias)
    y_steps = []
    state = jnp.zeros((b, w - 1, c))
    for t in range(l):
        y, state = L._causal_conv(x[:, t:t + 1], wgt, bias, state)
        y_steps.append(y[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(y_steps, 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# Positions
# --------------------------------------------------------------------------

def test_rope_preserves_norm(rng):
    x = jax.random.normal(rng, (2, 8, 4, 32))
    cos, sin = L.rope_cos_sin(jnp.arange(8)[None].repeat(2, 0), 32, 1e4)
    y = L.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)


def test_rope_relative_property(rng):
    """<rope(q, i), rope(k, j)> depends only on i - j."""
    q = jax.random.normal(rng, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (1, 1, 1, 16))

    def dot_at(i, j):
        ci, si = L.rope_cos_sin(jnp.array([[i]]), 16, 1e4)
        cj, sj = L.rope_cos_sin(jnp.array([[j]]), 16, 1e4)
        qi = L.apply_rope(q, ci, si)
        kj = L.apply_rope(k, cj, sj)
        return float((qi * kj).sum())

    assert abs(dot_at(5, 3) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-5


def test_partial_rope_passthrough(rng):
    x = jax.random.normal(rng, (1, 4, 2, 32))
    cos, sin = L.rope_cos_sin(jnp.arange(4)[None], 8, 1e4)   # 25% rotary
    y = L.apply_rope(x, cos, sin, fraction=0.25)
    np.testing.assert_array_equal(np.asarray(y[..., 8:]),
                                  np.asarray(x[..., 8:]))
    assert not np.allclose(np.asarray(y[..., :8]), np.asarray(x[..., :8]))


def test_mrope_sections_rotate_by_stream(rng):
    x = jax.random.normal(rng, (1, 3, 1, 16))
    # identical position streams == standard rope
    pos3 = jnp.broadcast_to(jnp.arange(3)[None, None], (3, 1, 3))
    cm, sm = L.mrope_cos_sin(pos3, 16, 1e4, (3, 3, 2))
    cs, ss = L.rope_cos_sin(jnp.arange(3)[None], 16, 1e4)
    np.testing.assert_allclose(np.asarray(cm), np.asarray(cs), atol=1e-6)
    # different streams differ
    pos3b = pos3.at[1].add(5)
    cm2, _ = L.mrope_cos_sin(pos3b, 16, 1e4, (3, 3, 2))
    assert not np.allclose(np.asarray(cm2), np.asarray(cm))


# --------------------------------------------------------------------------
# Ring buffer
# --------------------------------------------------------------------------

def test_ring_from_full_maps_positions(rng):
    B, Lf, S = 1, 10, 4
    full = jnp.arange(Lf, dtype=f32)[None, :, None]
    ring = L.ring_from_full(full, S)
    # position p lives at slot p % S; last S positions kept
    for p in range(Lf - S, Lf):
        assert float(ring[0, p % S, 0]) == p


def test_ring_from_full_short_seq(rng):
    full = jnp.arange(3, dtype=f32)[None, :, None]
    ring = L.ring_from_full(full, 8)
    assert float(ring[0, 0, 0]) == 0 and float(ring[0, 2, 0]) == 2
    assert float(jnp.abs(ring[0, 3:]).sum()) == 0


# --------------------------------------------------------------------------
# Blocked flash (jnp)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 48])
def test_flash_jnp_vs_dense(window, rng):
    B, H, Lq, D = 2, 4, 128, 32
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, Lq, H, D))
    k = jax.random.normal(ks[1], (B, Lq, H, D))
    v = jax.random.normal(ks[2], (B, Lq, H, D))
    out = L.flash_attention_jnp(q, k, v, scale=0.2, window=window,
                                block_q=32, block_k=32)
    mask = L.causal_mask(Lq, Lq, window=window)[None, None, None]
    want = L.attention(q, k, v, scale=0.2, mask=mask).reshape(B, Lq, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_flash_jnp_grad_matches_dense(rng):
    B, H, Lq, D = 1, 2, 64, 16
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, Lq, H, D))
    k = jax.random.normal(ks[1], (B, Lq, H, D))
    v = jax.random.normal(ks[2], (B, Lq, H, D))

    def f_flash(q):
        return L.flash_attention_jnp(q, k, v, scale=0.25, block_q=16,
                                     block_k=16).sum()

    def f_dense(q):
        mask = L.causal_mask(Lq, Lq)[None, None, None]
        return L.attention(q, k, v, scale=0.25, mask=mask).sum()

    g1 = jax.grad(f_flash)(q)
    g2 = jax.grad(f_dense)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-3,
                               atol=1e-4)


def test_moe_aux_loss_uniform_router():
    """A perfectly uniform router gives aux loss ~= 1 (Switch norm)."""
    import repro.configs as C
    import dataclasses
    cfg = dataclasses.replace(C.get_smoke("granite-moe-3b-a800m"),
                              capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    params = L.moe_init(key, cfg)
    params["router"] = jnp.zeros_like(params["router"])
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    y, aux = L.moe_apply(params, cfg, x)
    assert y.shape == x.shape
    assert abs(float(aux) - 1.0) < 0.05
