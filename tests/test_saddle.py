"""Saddle-escape verification testbed (DESIGN.md §14): planted-saddle
family analytics, the second-order trace lane, the saddle_push attack,
engine-vs-loop equivalence, and the theorem-level escape/stall
separation.

The concrete analytic tests here are the always-run twins of the
hypothesis properties in ``test_property.py`` (hypothesis is an optional
dev dependency)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import common
from repro.campaign import engine
from repro.campaign.scenario import Scenario, scenario_id
from repro.core import attacks as atk_lib
from repro.data import saddle as sad


# ------------------------------------------------------ family analytics


@pytest.mark.parametrize("kind", sad.SADDLE_TASKS)
def test_analytic_grad_matches_autodiff(kind):
    task = sad.make_saddle_task(12, kind, seed=3)
    for gap in (0.3, 1.0):
        for i in range(4):
            x = jax.random.normal(jax.random.PRNGKey(i), (12,))
            want = jax.grad(lambda z: sad.saddle_value(task, z, gap))(x)
            got = sad.saddle_grad(task, x, gap)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", sad.SADDLE_TASKS)
def test_min_eig_proxy_brackets_planted_lambda_min(kind):
    """At the saddle the Rayleigh proxy is exactly the planted
    lambda_min = -gap; everywhere else it stays >= -gap (the quartic
    only adds positive curvature)."""
    task = sad.make_saddle_task(12, kind)
    gap = 0.7
    x0 = sad.x_init(task)["x"]
    assert float(sad.min_eig_proxy(task, x0, gap)) == pytest.approx(-gap)
    for i in range(6):
        x = 2.0 * jax.random.normal(jax.random.PRNGKey(i), (12,))
        assert float(sad.min_eig_proxy(task, x, gap)) >= -gap - 1e-6


def test_chain_escape_iff_proxy_nonneg():
    """saddle_chain's escape radius is the inflection of each well, so
    the predicate and the proxy crossing 0 coincide by construction."""
    task = sad.make_saddle_task(10, "saddle_chain")
    gap = 1.0
    radii = np.asarray(sad.escape_radii(task, gap))
    for scale in (0.5, 0.99, 1.01, 2.0):
        u = scale * radii
        x = task.dirs.T @ jnp.asarray(u, jnp.float32)
        esc = bool(sad.escaped(task, x, gap))
        proxy = float(sad.min_eig_proxy(task, x, gap))
        assert esc == (proxy >= -1e-6), scale
        assert esc == (scale >= 1.0)


@pytest.mark.parametrize("kind", sad.SADDLE_TASKS)
def test_escaped_invariant_under_symmetry_group(kind):
    """Reflections u_j -> -u_j across any planted hyperplane and motion
    in the bulk complement leave the predicate unchanged."""
    task = sad.make_saddle_task(12, kind, seed=1)
    gap = 0.8
    for i in range(5):
        x = 1.5 * jax.random.normal(jax.random.PRNGKey(i), (12,))
        u = task.dirs @ x
        base = bool(sad.escaped(task, x, gap))
        for j in range(task.k):                       # reflect stage j
            flip = x - 2.0 * u[j] * task.dirs[j]
            assert bool(sad.escaped(task, flip, gap)) == base
        # bulk translation: v orthogonal to every planted direction
        v = jax.random.normal(jax.random.PRNGKey(100 + i), (12,))
        v = v - task.dirs.T @ (task.dirs @ v)
        assert bool(sad.escaped(task, x + 3.0 * v, gap)) == base


def test_noise_model_zero_mean_over_seeds():
    """IID gradient-noise model: the worker noise eps averages to 0 over
    seeds, so E[g_i] is the analytic gradient."""
    task = sad.make_saddle_task(8, "saddle_quad")
    acc = np.zeros((8,))
    n = 300
    for seed in range(n):
        b = sad.saddle_batch(task, sad.step_key(seed, 0), batch=20, m=10)
        acc += np.asarray(b["eps"]).mean(axis=(0, 1))
    assert np.abs(acc / n).max() < 0.02


def test_anchor_step_and_vr_scale():
    """SVRG reduction: period<=1 is plain SGD; period p>=2 pins the key
    to the last refresh and scales the reference noise."""
    assert int(sad.anchor_step(7, 0)) == 7
    assert int(sad.anchor_step(7, 1)) == 7
    assert int(sad.anchor_step(7, 4)) == 4
    assert int(sad.anchor_step(8, 4)) == 8
    assert float(sad.vr_scale(0)) == 1.0
    assert float(sad.vr_scale(4)) == sad.VR_REF_SCALE


def test_iterator_twin_matches_engine_batch_fn():
    """saddle_batches shares the engine batch_fn's key schedule and
    anchoring — bit-identical batches."""
    task = sad.make_saddle_task(8, "saddle_chain")
    it = sad.saddle_batches(task, 40, seed=5, m=10, vr_period=4)
    for t in range(10):
        got = next(it)
        ta = sad.anchor_step(t, 4)
        want = sad.saddle_batch(task, sad.step_key(5, ta), 40, 10,
                                scale=sad.vr_scale(4))
        assert np.array_equal(np.asarray(got["eps"]),
                              np.asarray(want["eps"])), t


def test_escape_budget_monotone_and_positive():
    task = sad.make_saddle_task(12, "saddle_chain")
    b = sad.escape_budget(task, 1.0, 0.1, u0=0.005)
    assert b > 0
    # smaller gap / lr / start -> more steps
    assert sad.escape_budget(task, 0.5, 0.1, u0=0.005) > b
    assert sad.escape_budget(task, 1.0, 0.05, u0=0.005) > b
    assert sad.escape_budget(task, 1.0, 0.1, u0=0.0005) > b


# ------------------------------------------------- scenario validation


def test_saddle_scenario_validation():
    with pytest.raises(ValueError, match="unknown task"):
        Scenario(attack="none", defense="mean", task="saddle_cubic")
    with pytest.raises(ValueError, match="unknown perturb"):
        Scenario(attack="none", defense="mean", perturb="langevin")
    with pytest.raises(ValueError, match="data attack"):
        Scenario(attack="label_flip", defense="mean", task="saddle_quad")
    with pytest.raises(ValueError, match="teacher-task axis"):
        Scenario(attack="none", defense="mean", task="saddle_chain",
                 hetero="dirichlet")
    with pytest.raises(ValueError, match="planted escape directions"):
        Scenario(attack="saddle_push", defense="mean")
    Scenario(attack="saddle_push", defense="mean", task="saddle_quad")


def test_saddle_fields_excluded_from_default_scenario_id():
    """Pre-PR literal hash pins: the new task/perturb/saddle knobs are
    defaulted out of scenario_id, so every cell stored before this PR
    keeps its id (store resume untouched)."""
    s = Scenario(attack="sign_flip", defense="safeguard_double", steps=40)
    assert scenario_id(s) == "f5e3f7a6f4ccc757"
    assert scenario_id(Scenario(attack="none", defense="mean")) == \
        "bd534c8b367be945"
    # and the new knobs do enter the hash when set
    ids = {scenario_id(x) for x in (
        s,
        dataclasses.replace(s, task="saddle_quad"),
        dataclasses.replace(s, task="saddle_quad", saddle_gap=1.0),
        dataclasses.replace(s, task="saddle_quad", noise_r=0.1),
        dataclasses.replace(s, task="saddle_quad", vr_period=4),
        dataclasses.replace(s, perturb="sgd_escape"),
        dataclasses.replace(s, perturb="sgd_escape", escape_nu=0.3),
        dataclasses.replace(s, perturb="sgd_escape", escape_thresh=0.5),
    )}
    assert len(ids) == 8


# ------------------------------------------------- engine equivalence


LOOP_KW = dict(steps=40, seed=3, gap=1.0, noise_r=0.05, vr_period=4,
               escape_nu=0.1, adapt_init=1.0)


@pytest.mark.parametrize("kind,attack,defense,perturb", [
    ("saddle_chain", "saddle_push", "safeguard_double", "sgd_escape"),
    ("saddle_quad", "none", "mean", "sgd_escape"),
    ("saddle_chain", "saddle_push", "mean", "none"),
    ("saddle_quad", "sign_flip", "zeno", "none"),
])
def test_engine_matches_saddle_loop(kind, attack, defense, perturb):
    """Engine-vs-Trainer equivalence of the saddle lane: same rng
    streams and op order, so the discrete traces (escape predicate,
    filter decisions — including the saddle_push boost controller's
    effects) are exact and the float traces agree to XLA-fusion ulps."""
    kw = dict(LOOP_KW, defense_name=defense, attack_name=attack,
              perturb=perturb)
    loop = common.run_saddle_loop(kind, **kw)
    scn = common.saddle_scenario_for(kind, **kw)
    eng = engine.run_scenarios([scn])[scenario_id(scn)]
    assert float(eng["acc"]) == loop["acc"]
    assert eng["escape_step"] == loop["escape_step"]
    for k in ("caught_byz", "evicted_honest"):
        if k in loop:
            assert eng[k] == loop[k], k
    # second-order lane present and exact; float lanes fusion-tight
    for k in ("escaped", "min_eig_proxy"):
        assert np.array_equal(np.asarray(eng["traces"][k]),
                              np.asarray(loop["traces"][k])), k
    for k in loop["traces"]:
        np.testing.assert_allclose(
            np.asarray(eng["traces"][k], np.float64),
            np.asarray(loop["traces"][k], np.float64),
            rtol=1e-4, atol=1e-6, err_msg=k)


def test_saddle_knobs_are_vmap_axes():
    """saddle_gap / noise_r / vr_period / escape_nu lanes share one
    program; vmapped lanes match the unbatched trajectories exactly on
    every discrete lane (filter decisions, the escape predicate, the
    stateful saddle_push boost's evictions) and to XLA-fusion ulps on
    the float lanes (the attack's ``dirs @ mu`` lowers gemv->gemm under
    vmap, changing the accumulation order — same as the safeguard_cclip
    composition precedent)."""
    scns = [Scenario(attack="saddle_push", defense="safeguard_double",
                     task="saddle_chain", d_in=12, steps=30, batch=40,
                     perturb="sgd_escape", adapt_init=1.0,
                     saddle_gap=g, noise_r=r, vr_period=p, escape_nu=nu)
            for g, r, p, nu in [(0.5, 0.05, 0, 0.1), (1.0, 0.05, 0, 0.1),
                                (1.0, 0.02, 4, 0.05)]]
    assert len(engine.group_scenarios(scns)) == 1
    batched = engine.run_scenarios(scns, batched=True)
    unbatched = engine.run_scenarios(scns, batched=False)
    discrete = ("escaped", "escape_on", "n_good", "caught_byz",
                "evicted_honest")
    for s in scns:
        b, u = batched[scenario_id(s)], unbatched[scenario_id(s)]
        for key in discrete:
            assert np.array_equal(b["traces"][key], u["traces"][key]), \
                (s.saddle_gap, s.vr_period, key)
        for key in b["traces"]:
            np.testing.assert_allclose(
                np.asarray(b["traces"][key], np.float64),
                np.asarray(u["traces"][key], np.float64),
                rtol=1e-5, atol=1e-6,
                err_msg=f"{s.saddle_gap}/{s.vr_period}/{key}")
        assert b["acc"] == u["acc"]
        assert b["escape_step"] == u["escape_step"]
    # the traced gap changes the outcome (not a dead knob)
    a, b2 = (batched[scenario_id(s)] for s in scns[:2])
    assert not np.array_equal(a["traces"]["min_eig_proxy"],
                              b2["traces"]["min_eig_proxy"])


def test_second_order_lane_trace_shapes():
    scn = Scenario(attack="none", defense="safeguard_double",
                   task="saddle_quad", d_in=8, steps=25, batch=40,
                   perturb="sgd_escape")
    rec = engine.run_scenarios([scn])[scenario_id(scn)]
    for key in ("true_grad_norm", "min_eig_proxy", "escaped",
                "escape_on", "loss", "n_good"):
        assert rec["traces"][key].shape == (25,), key
    assert "escape_step" in rec and "min_eig_final" in rec


def test_teacher_path_unchanged_by_saddle_plumbing():
    """The perturb/saddle knobs default off: a teacher scenario traces no
    second-order lane, consumes no extra rng split, and batch-keys apart
    from saddle scenarios."""
    t = Scenario(attack="sign_flip", defense="mean", steps=10)
    rec = engine.run_scenarios([t])[scenario_id(t)]
    assert "escaped" not in rec["traces"]
    assert "escape_on" not in rec["traces"]
    s = Scenario(attack="none", defense="mean", task="saddle_quad",
                 steps=10, batch=40)
    assert len(engine.group_scenarios(
        [t, dataclasses.replace(t, attack="none")] + [s])) > 1


# ------------------------------------------- saddle_push attack unit


def test_saddle_push_cancels_honest_escape_component():
    """With boost = n_b/n_h-normalized cancellation, the aggregated mean
    over all workers has zero component along the planted directions and
    the honest bulk component survives."""
    task = sad.make_saddle_task(10, "saddle_quad", seed=2)
    atk = atk_lib.make_saddle_push(task.dirs, boost_init=1.0)
    m = 10
    byz = jnp.arange(m) < 4
    g = jax.random.normal(jax.random.PRNGKey(0), (m, 10))
    state = atk.init({"x": jnp.zeros((10,))})
    out, _ = atk.act({"x": g}, byz, state, jnp.int32(0), jax.random.PRNGKey(1))
    mixed = np.asarray(out["x"])
    honest_mean = np.asarray(g)[4:].mean(axis=0)
    total_mean = mixed.mean(axis=0)
    q = np.asarray(task.dirs)
    # escape component cancelled, bulk untouched
    np.testing.assert_allclose(q @ total_mean, 0.0, atol=1e-6)
    bulk = lambda v: v - q.T @ (q @ v)  # noqa: E731
    np.testing.assert_allclose(bulk(total_mean), bulk(honest_mean),
                               atol=1e-6)
    # honest rows pass through untouched
    np.testing.assert_array_equal(mixed[4:], np.asarray(g)[4:])


def test_saddle_push_boost_ramps_on_null_feedback():
    """Against a filterless defense the boost controller sees null
    feedback and ramps toward its cap; a fresh eviction halves it."""
    task = sad.make_saddle_task(6, "saddle_quad")
    atk = atk_lib.make_saddle_push(task.dirs, boost_init=1.0)
    byz = jnp.arange(6) < 2
    state = atk.init({"x": jnp.zeros((6,))})
    null = atk_lib.null_feedback(6)
    for _ in range(60):
        state = atk.observe(state, null, byz)
    assert float(state["boost"]) == pytest.approx(8.0)   # boost_max
    caught = dict(null, good=jnp.arange(6) >= 2)         # fresh evictions
    state = atk.observe(state, caught, byz)
    assert float(state["boost"]) == pytest.approx(4.0)


# --------------------------------------------- theorem-level separation


@pytest.mark.slow
def test_escape_time_separation_regression():
    """The paper's headline separation, locked as a regression: on the
    chained planted-saddle task SafeguardSGD with the sgd_escape
    perturbation escapes within the theorem's predicted step budget on
    every seed — clean AND under the curvature-aware saddle_push
    colluders — while the undefended mean under saddle_push never
    escapes (the colluders cancel the escape component and the iterate
    stays pinned at the strict saddle, min_eig_proxy = -gap)."""
    kind, steps, seeds = "saddle_chain", 500, 3
    gap, lr, nu = 1.0, 0.1, 0.1
    task = sad.make_saddle_task(16, kind)
    budget = sad.escape_budget(task, gap, lr, u0=lr * nu / 2)
    assert budget <= steps

    def cells(dfn, atk_name, pert):
        return [common.saddle_scenario_for(
            kind, steps=steps, seed=k, gap=gap, noise_r=0.05, lr=lr,
            defense_name=dfn, attack_name=atk_name, perturb=pert,
            escape_nu=nu, adapt_init=1.0) for k in range(seeds)]

    sg_clean = cells("safeguard_double", "none", "sgd_escape")
    sg_atk = cells("safeguard_double", "saddle_push", "sgd_escape")
    mean_atk = cells("mean", "saddle_push", "none")
    res = engine.run_scenarios(sg_clean + sg_atk + mean_atk)

    for s in sg_clean + sg_atk:
        rec = res[scenario_id(s)]
        assert 0 < rec["escape_step"] <= budget, (s.attack, s.seed,
                                                  rec["escape_step"], budget)
        assert rec["min_eig_final"] >= 0.0      # at an approx local min
    for s in sg_atk:                            # colluders evicted
        assert res[scenario_id(s)]["caught_byz"] == common.N_BYZ, s.seed
    for s in mean_atk:                          # provable stall
        rec = res[scenario_id(s)]
        assert rec["escape_step"] == -1, s.seed
        assert rec["acc"] == 0.0
        # pinned in the noise ball around the strict saddle: the planted
        # curvature still reads ~ -gap (vs >= 0 after an escape)
        assert rec["min_eig_final"] == pytest.approx(-gap, abs=1e-2)
