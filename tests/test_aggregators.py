"""Baseline aggregator correctness vs numpy oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregators as agg
from repro.core import tree_utils as tu

M, D1, D2 = 9, 7, 4


@pytest.fixture
def grads(rng):
    k1, k2 = jax.random.split(rng)
    return {"a": jax.random.normal(k1, (M, D1, D2)),
            "b": jax.random.normal(k2, (M, D1))}


def flat(g):
    return np.asarray(tu.tree_stack_flatten(g))


def test_mean(grads):
    out = agg.mean(grads)
    np.testing.assert_allclose(np.asarray(out["a"]),
                               flat(grads)[:, :D1 * D2].mean(0).reshape(D1, D2),
                               rtol=1e-5)


def test_coordinate_median(grads):
    out = agg.coordinate_median(grads)
    want = np.median(np.asarray(grads["a"]), axis=0)
    np.testing.assert_allclose(np.asarray(out["a"]), want, atol=1e-6)


def test_trimmed_mean(grads):
    out = agg.trimmed_mean(grads, trim=2)
    s = np.sort(np.asarray(grads["a"]), axis=0)[2:M - 2]
    np.testing.assert_allclose(np.asarray(out["a"]), s.mean(0), atol=1e-5)


def test_trimmed_mean_rejects_overtrim(grads):
    with pytest.raises(ValueError):
        agg.trimmed_mean(grads, trim=5)


def test_geometric_medoid(grads):
    out = agg.geometric_medoid(grads)
    F = flat(grads)
    dists = np.sqrt(((F[:, None] - F[None]) ** 2).sum(-1)).sum(1)
    best = int(np.argmin(dists))
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(grads["a"][best]), atol=1e-6)


def test_weiszfeld_reduces_objective(grads):
    F = flat(grads)

    def obj(y):
        return np.sqrt(((F - y[None]) ** 2).sum(-1)).sum()

    y0 = F.mean(0)
    y = agg.geometric_median(grads, iters=32)
    y_flat = np.concatenate([np.asarray(y["a"]).ravel(),
                             np.asarray(y["b"]).ravel()])
    assert obj(y_flat) <= obj(y0) + 1e-4


def test_krum_selects_inlier():
    key = jax.random.PRNGKey(3)
    g = {"w": 0.05 * jax.random.normal(key, (M, D1))}
    # 3 byzantine workers far away
    g["w"] = g["w"].at[:3].add(50.0)
    out = agg.krum(g, n_byz=3)
    assert float(jnp.abs(out["w"]).max()) < 1.0


def test_krum_matches_bruteforce(grads):
    b = 2
    out = agg.krum(grads, n_byz=b)
    F = flat(grads)
    sq = ((F[:, None] - F[None]) ** 2).sum(-1)
    np.fill_diagonal(sq, np.inf)
    k = M - b - 2
    scores = np.sort(sq, axis=1)[:, :k].sum(1)
    best = int(np.argmin(scores))
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(grads["a"][best]), atol=1e-6)


def test_zeno_keeps_top_scores(grads):
    scores = jnp.arange(M, dtype=jnp.float32)       # worker M-1 best
    out = agg.zeno(grads, scores, n_byz=4)
    want = jax.tree.map(lambda g: g[4:].mean(0), grads)
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(want["a"]), atol=1e-5)


def test_registry(grads):
    reg = agg.make_registry(n_byz=3, m=M)
    for name, a in reg.items():
        if a.needs_scores:
            out = a.fn(grads, scores=jnp.zeros((M,)))
        else:
            out = a.fn(grads)
        assert out["a"].shape == (D1, D2), name
        assert bool(jnp.isfinite(out["a"]).all()), name
