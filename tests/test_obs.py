"""Flight-recorder subsystem (DESIGN.md §15): typed metric schema,
trace sidecars, event extraction, forensics reports, profiling — plus
the satellite regressions (store `_jsonify` round-trip, `scan_trial`
trace-field validation, `Trainer` vector-metric routing)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.campaign import run as campaign_run
from repro.campaign.engine import run_scenarios
from repro.campaign.scenario import Scenario, scenario_id
from repro.campaign.store import CampaignStore, _jsonify
from repro.configs.base import TrainConfig
from repro.core import attacks as atk_lib
from repro.core import defenses as dfn_lib
from repro.data import tasks
from repro.data.pipeline import worker_split
from repro.obs import (Event, MetricSpec, SchemaError, caught_curve,
                       events_from_json, events_to_json, extract_events,
                       register_metric, replay_good, summarize,
                       validate_info, validate_metrics)
from repro.obs import events as ev_lib
from repro.obs import profile as prof
from repro.obs import report as report_lib
from repro.obs import schema as schema_lib
from repro.obs import trace as trace_lib
from repro.optim import make_optimizer
from repro.train import Trainer, init_train_state, make_train_step, \
    scan_trial

M, NBYZ = 10, 4
BYZ = jnp.arange(M) < NBYZ


# ------------------------------------------------------------- schema


def test_schema_accepts_canonical_step_metrics():
    metrics = {"loss": jnp.zeros(()), "n_good": jnp.zeros(()),
               "caught_byz": jnp.zeros((), jnp.int32),
               "good": jnp.ones((M,), bool),
               "dist_to_med_B": jnp.zeros((M,)),
               "threshold_B": jnp.zeros(())}
    assert validate_metrics(metrics, M) is metrics


def test_schema_rejects_unknown_name():
    with pytest.raises(SchemaError, match="not_a_metric"):
        validate_metrics({"not_a_metric": jnp.zeros(())}, M)


def test_schema_rejects_wrong_shape_class():
    # dist_to_med_B is per_worker: a scalar violates the shape class
    with pytest.raises(SchemaError, match="dist_to_med_B"):
        validate_metrics({"dist_to_med_B": jnp.zeros(())}, M)
    # and a per-worker loss is just as wrong
    with pytest.raises(SchemaError, match="loss"):
        validate_metrics({"loss": jnp.zeros((M,))}, M)


def test_schema_rejects_wrong_dtype_kind():
    with pytest.raises(SchemaError, match="caught_byz"):
        validate_metrics({"caught_byz": jnp.zeros((), jnp.float32)}, M)


def test_schema_dtype_by_kind_not_exact():
    # an at-scale bf16 loss is the same metric (kind: floating)
    validate_metrics({"loss": jnp.zeros((), jnp.bfloat16)}, M)


def test_schema_info_surface_and_per_bucket():
    info = {"good": jnp.ones((M,), bool),
            "n_good": jnp.asarray(float(M)),
            "bucket_good": jnp.ones((M // 2,), bool)}
    assert validate_info(info, M) is info
    with pytest.raises(SchemaError, match="bucket_good"):
        # length must divide m
        validate_info({"bucket_good": jnp.ones((3,), bool)}, M)


def test_register_metric_refuses_silent_redefinition():
    spec = MetricSpec("test_only_metric", "float32", schema_lib.SCALAR,
                      "probe")
    register_metric(spec)
    try:
        with pytest.raises(SchemaError, match="already registered"):
            register_metric(spec)
        register_metric(spec, overwrite=True)      # explicit is fine
    finally:
        del schema_lib.METRICS["test_only_metric"]


# ------------------------------------------------------------- events


def _synthetic_traces(steps=12, m=4):
    """Hand-built dense traces with one eviction, one restoration, a
    re-eviction, an escape firing, and a controller reversal."""
    good = np.ones((steps, m), bool)
    good[3:6, 1] = False           # evicted at 3
    good[6:, 1] = True             # restored at 6
    good[8:, 2] = False            # evicted at 8
    dist = np.full((steps, m), 0.1, np.float32)
    th = np.full((steps,), 1.0, np.float32)
    dist[3, 1] = 1.5               # guard-B trigger for the eviction
    dist[8, 2] = 2.5
    esc = np.zeros((steps,), np.float32)
    esc[5:7] = 1.0                 # one rising edge at 5
    lvl = np.array([1, 2, 3, 4, 3, 2, 3, 4, 5, 6, 6, 6], np.float64)
    return {"good": good, "dist_to_med_B": dist, "threshold_B": th,
            "escape_on": esc, "grad_norm": np.ones((steps,), np.float32),
            "attack_level": lvl, "caught_byz": (~good[:, :2]).sum(1)}


def test_extract_events_taxonomy():
    traces = _synthetic_traces()
    events = extract_events(traces)
    kinds = {}
    for e in events:
        kinds.setdefault(e.kind, []).append(e)
    ev1, ev2 = kinds["eviction"]
    assert (ev1.step, ev1.worker, ev1.guard) == (3, 1, "B")
    assert ev1.value == pytest.approx(1.5) and ev1.threshold == 1.0
    assert (ev2.step, ev2.worker) == (8, 2)
    (res,) = kinds["restoration"]
    assert (res.step, res.worker) == (6, 1)
    assert [(e.step, e.worker) for e in kinds["threshold_crossing"]] == \
        [(3, 1), (8, 2)]
    (esc,) = kinds["escape_fire"]
    assert esc.step == 5 and esc.worker == ev_lib.GLOBAL
    # level ramps 1..4, reverses down at t=4, reverses up again at t=6
    assert [e.step for e in kinds["attack_phase_change"]] == [4, 6]


def test_replay_good_bit_matches():
    traces = _synthetic_traces()
    events = extract_events(traces)
    assert np.array_equal(replay_good(events, 4, 12), traces["good"])


def test_single_guard_mirror_suppressed():
    """safeguard_single publishes guard A as a copy of guard B; the
    extractor must not double-count its events."""
    traces = _synthetic_traces()
    traces["dist_to_med_A"] = traces["dist_to_med_B"].copy()
    traces["threshold_A"] = traces["threshold_B"].copy()
    events = extract_events(traces)
    crossings = [e for e in events if e.kind == "threshold_crossing"]
    assert {e.guard for e in crossings} == {"B"}
    evictions = [e for e in events if e.kind == "eviction"]
    assert all(e.guard == "B" for e in evictions)


def _canon(records):
    return json.dumps(records, sort_keys=True)


def test_events_json_roundtrip_exact():
    events = extract_events(_synthetic_traces())
    back = events_from_json(json.loads(json.dumps(events_to_json(events))))
    # canonical-json compare: NaN fields defeat `==` (nan != nan), but
    # f32 -> f64 widening is lossless so the strings are bit-faithful
    assert _canon(events_to_json(back)) == _canon(events_to_json(events))


def test_summarize_counts():
    traces = _synthetic_traces()
    s = summarize(extract_events(traces), n_byz=2, m=4)
    assert s["caught"][1]["step"] == 3           # worker 1 is byzantine
    assert s["n_caught"] == 1
    assert s["false_evictions"] == {2: 8}        # worker 2 is honest
    assert s["restorations"] == 1
    assert s["attack_phase_changes"] == 2
    assert s["escape_fires"] == 1


# --------------------------------------------- acceptance: engine cell


@pytest.fixture(scope="module")
def variance_cell():
    scn = Scenario(attack="variance", defense="safeguard_double",
                   steps=40)
    rec = run_scenarios([scn])[scenario_id(scn)]
    return scn, rec


def test_variance_cell_events_name_every_colluder(variance_cell):
    """ISSUE 7 acceptance: the event layer names every caught colluder
    with eviction step and triggering guard/threshold, matching the
    trainer's caught_byz trace exactly."""
    scn, rec = variance_cell
    events = events_from_json(rec["events"])
    traces = {k: np.asarray(v) for k, v in rec["traces"].items()}

    # the record's stored events ARE the re-extraction (bit-match)
    assert _canon(rec["events"]) == _canon(
        events_to_json(extract_events(traces)))

    # replay matches the trainer's own timeline bit-for-bit
    assert np.array_equal(replay_good(events, scn.m, scn.steps),
                          traces["good"].astype(bool))
    assert np.array_equal(
        caught_curve(events, scn.n_byz, scn.m, scn.steps),
        traces["caught_byz"])

    s = summarize(events, n_byz=scn.n_byz, m=scn.m)
    final_caught = int(traces["caught_byz"][-1])
    assert final_caught > 0                      # the attack IS detected
    assert s["n_caught"] >= final_caught
    for k, c in s["caught"].items():
        assert k < scn.n_byz
        assert c["guard"] in ("B", "A", "BA")
        assert c["dist"] >= c["threshold"]


def test_eviction_forensics_narrative(variance_cell):
    scn, rec = variance_cell
    traces = {k: np.asarray(v) for k, v in rec["traces"].items()}
    s = summarize(events_from_json(rec["events"]), n_byz=scn.n_byz,
                  m=scn.m)
    worker, info = next(iter(s["caught"].items()))
    text = report_lib.eviction_forensics(traces, worker)
    assert f"worker {worker} evicted at step {info['step']}" in text
    assert "dist_B" in text and "thresh_B" in text
    # an honest, never-evicted worker gets the negative narrative
    text2 = report_lib.eviction_forensics(traces, scn.m - 1)
    assert "never evicted" in text2


# ------------------------------------------- store + report, end to end


@pytest.fixture(scope="module")
def traced_campaign(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("obs_store"))
    out = campaign_run.main(["--campaign", "smoke", "--steps", "25",
                             "--seeds", "1", "--root", root,
                             "--store-traces"])
    assert out["ran"] > 0
    return root


def test_sidecars_written_and_loadable(traced_campaign):
    store = CampaignStore("smoke", root=traced_campaign)
    records = store.load()
    for sid, rec in records.items():
        assert "traces" not in rec["result"]     # not inlined
        traces = store.load_traces(sid)
        assert traces is not None
        assert traces["loss"].shape == (25,)
        assert traces["loss"].dtype == np.float32     # dtype preserved
        assert rec["result"]["trace_fields"] == sorted(traces)


def test_report_check_events_passes(traced_campaign):
    assert report_lib.main(["--campaign", "smoke",
                            "--root", traced_campaign,
                            "--check-events"]) == 0


def test_campaign_report_renders(traced_campaign):
    store = CampaignStore("smoke", root=traced_campaign)
    text = report_lib.campaign_report(store, store.load())
    assert "# obs report" in text
    assert "| cell |" in text


def test_resume_leaves_sidecars_untouched(traced_campaign):
    import glob
    import os
    paths = sorted(glob.glob(os.path.join(traced_campaign, "smoke",
                                          "traces", "*.npz")))
    assert paths
    before = {p: (os.path.getmtime(p), open(p, "rb").read())
              for p in paths}
    out = campaign_run.main(["--campaign", "smoke", "--steps", "25",
                             "--seeds", "1", "--root", traced_campaign,
                             "--store-traces"])
    assert out["ran"] == 0                       # full resume
    for p in paths:
        assert open(p, "rb").read() == before[p][1]


# ------------------------------------------------- _jsonify (satellite)


def test_jsonify_roundtrip_regression():
    payload = {
        "f": np.float32(1.5), "i": np.int64(3), "b": np.bool_(True),
        "jax_scalar": jnp.asarray(2.5),
        "nested": {"arr": np.array([True, False]),
                   "list": [np.float32(0.25), {"deep": jnp.arange(3)}]},
        "nan": float("nan"), "inf": np.float32(np.inf),
        "none": None, "s": "str",
    }
    out = _jsonify(payload)
    back = json.loads(json.dumps(out))
    assert back["f"] == 1.5 and back["i"] == 3 and back["b"] is True
    assert back["jax_scalar"] == 2.5
    assert back["nested"]["arr"] == [True, False]
    assert back["nested"]["list"][1]["deep"] == [0, 1, 2]
    assert np.isnan(back["nan"]) and np.isinf(back["inf"])
    assert back["none"] is None and back["s"] == "str"
    # bool stays bool even though bool < int in the isinstance chain
    assert type(out["b"]) is bool and type(out["i"]) is int


def test_jsonify_loud_on_unknown_type():
    with pytest.raises(TypeError, match=r"\$\.a\[1\]"):
        _jsonify({"a": [1, object()]})


# -------------------------------------- scan_trial + Trainer (satellites)


@pytest.fixture(scope="module")
def tiny_setup():
    task = tasks.make_teacher_task(d_in=8, d_hidden=8, n_classes=4)
    opt = make_optimizer(TrainConfig(lr=0.1))
    defense = dfn_lib.make_registry(M, NBYZ, T0=5, T1=15)[
        "safeguard_double"]
    attack = atk_lib.make_registry()["variance"]
    params = tasks.student_init(task)
    state = init_train_state(params, opt, defense=defense, attack=attack)
    step = make_train_step(tasks.mlp_loss, opt, byz_mask=BYZ,
                           defense=defense, attack=attack, jit=False)

    def batch_fn(t):
        key = jax.random.fold_in(jax.random.PRNGKey(0xDA7A), t)
        return worker_split(tasks.teacher_batch(task, key, 50), M)
    return task, state, step, batch_fn


def test_scan_trial_trace_fields_subset(tiny_setup):
    _, state, step, batch_fn = tiny_setup
    _, traces = scan_trial(step, state, batch_fn=batch_fn, steps=6,
                           trace_fields=("loss", "good"))
    assert sorted(traces) == ["good", "loss"]
    assert traces["loss"].shape == (6,)
    assert traces["good"].shape == (6, M)


def test_scan_trial_unknown_field_named_error(tiny_setup):
    _, state, step, batch_fn = tiny_setup
    with pytest.raises(ValueError, match="unknown trace field.*typo_xyz"):
        scan_trial(step, state, batch_fn=batch_fn, steps=6,
                   trace_fields=("loss", "typo_xyz"))


def test_scan_trial_empty_trace_fields_drops_memory(tiny_setup):
    _, state, step, batch_fn = tiny_setup
    final, traces = scan_trial(step, state, batch_fn=batch_fn, steps=6,
                               trace_fields=())
    assert traces == {}
    assert int(final.step) == 6                  # trial still ran


def test_trainer_routes_vector_metrics(tiny_setup, capsys):
    task, state, step, _ = tiny_setup
    it = tasks.teacher_batches(task, 50, m=M)
    tr = Trainer(state, jax.jit(step), it, log_every=10 ** 9, name="obs")
    tr.run(4, verbose=True)
    out = capsys.readouterr().out
    assert "routed to .traces" in out
    assert out.count("routed to .traces") == 1   # surfaced once per run
    # history holds scalars only; vectors landed in traces
    assert all(np.ndim(v) == 0 for rec in tr.history
               for v in rec.values())
    arrs = tr.trace_arrays()
    assert arrs["good"].shape == (4, M)
    assert arrs["dist_to_med_B"].shape == (4, M)
    # the routed traces feed the event layer directly
    extract_events(arrs)


# ----------------------------------------------------------- profiling


def test_phase_timer_disjoint_nesting():
    pt = prof.PhaseTimer()
    with pt.phase("outer"):
        with pt.phase("inner"):
            pass
    s = pt.summary()
    assert set(pt.seconds) == {"outer", "inner"}
    assert s["total_s"] >= 0
    assert abs(s["outer_frac"] + s["inner_frac"] - 1.0) < 1e-3


def test_profile_compiled_reports_phases():
    def f(x):
        return (x * 2.0).sum()

    rec = prof.profile_compiled(f, jnp.ones((8, 8)), repeats=2,
                                analyze=False)
    assert rec["compile_s"] > 0 and rec["execute_s"] > 0
    assert float(rec["_out"]) == 128.0
    assert "_out" not in prof.strip_private(rec)


# ------------------------------------------------------- trace module


def test_save_load_traces_roundtrip(tmp_path):
    traces = {"a": np.arange(6, dtype=np.float32).reshape(3, 2),
              "b": np.array([True, False, True])}
    rel = trace_lib.save_traces(str(tmp_path), "sid123", traces)
    assert rel == trace_lib.trace_relpath("sid123")
    back = trace_lib.load_trace_file(
        trace_lib.trace_path(str(tmp_path), "sid123"))
    for k in traces:
        assert back[k].dtype == traces[k].dtype
        np.testing.assert_array_equal(back[k], traces[k])


def test_load_cell_traces_missing_sidecar_is_loud(tmp_path):
    rec = {"id": "x", "result": {"trace_file": "traces/x.npz"}}
    with pytest.raises(FileNotFoundError, match="x.npz"):
        trace_lib.load_cell_traces(str(tmp_path), rec)
