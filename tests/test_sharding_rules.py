"""Sharding-rule unit tests (no devices needed: rules are pure functions
of shapes/paths given a mesh-like object)."""

import types

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.launch import sharding as sh
from repro.models import transformer as T


class FakeMesh:
    def __init__(self, multi_pod=False):
        self.axis_names = (("pod", "data", "model") if multi_pod
                           else ("data", "model"))
        self.shape = dict(zip(self.axis_names,
                              (2, 16, 16) if multi_pod else (16, 16)))


@pytest.mark.parametrize("arch", C.ARCH_IDS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_are_valid(arch, multi_pod):
    cfg = C.get(arch)
    mesh = FakeMesh(multi_pod)
    abstract = T.init_abstract(cfg)
    specs = sh.params_pspecs(abstract, mesh)

    flat_a = jax.tree_util.tree_flatten_with_path(abstract)[0]
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_a) == len(flat_s)
    for (path, leaf), spec in zip(flat_a, flat_s):
        pstr = "/".join(str(p) for p in path)
        assert len(spec) == len(leaf.shape), (pstr, spec, leaf.shape)
        for dim, s in zip(leaf.shape, spec):
            if s is None:
                continue
            axes = s if isinstance(s, tuple) else (s,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (pstr, spec, leaf.shape)
        # a mesh axis may appear at most once per spec
        used = [a for s in spec if s is not None
                for a in (s if isinstance(s, tuple) else (s,))]
        assert len(used) == len(set(used)), (pstr, spec)


def test_stack_axis_never_sharded():
    cfg = C.get("granite-34b")
    mesh = FakeMesh()
    abstract = T.init_abstract(cfg)
    specs = sh.params_pspecs(abstract, mesh)
    blocks = specs["blocks"]
    for spec in jax.tree_util.tree_leaves(
            blocks, is_leaf=lambda x: isinstance(x, P)):
        if len(spec) >= 1:
            assert spec[0] is None


def test_expert_dim_gets_model_axis():
    cfg = C.get("deepseek-v2-236b")
    mesh = FakeMesh()
    abstract = T.init_abstract(cfg)
    specs = sh.params_pspecs(abstract, mesh)
    w_gate = specs["blocks"]["moe"]["w_gate"]
    assert w_gate[1] == "model"        # 160 experts over 16-way model axis


def test_stacked_grad_spec_moves_worker_to_data():
    mesh = FakeMesh()
    spec = P(None, "data", "model")
    out = sh.stacked_grad_pspec(spec, mesh)
    assert out[0] == "data"
    assert out[1:] == (None, None, "model")


def test_cache_specs_shard_batch_and_heads():
    cfg = C.get("deepseek-coder-33b")
    mesh = FakeMesh()
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 128, 1024))
    specs = sh.cache_pspecs(cache, mesh, 128)
    kspec = specs["blocks"]["k"]
    assert kspec[0] is None            # layer-stack axis
    assert kspec[1] == "data"          # batch
    assert "model" in tuple(kspec)     # one of the big dims


def test_cache_specs_b1_replicated_batch():
    cfg = C.get("mamba2-130m")
    mesh = FakeMesh()
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 1, 1024))
    specs = sh.cache_pspecs(cache, mesh, 1)
    sspec = specs["blocks"]["ssm"]
    assert sspec[1] is None            # B=1 cannot shard
