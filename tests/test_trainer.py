"""End-to-end training behaviour: the paper's qualitative claims on the
teacher-student task (Section 5, scaled down)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import TrainConfig
from repro.core import SafeguardConfig
from repro.core import aggregators as agg_lib
from repro.core import attacks as atk_lib
from repro.data import tasks
from repro.optim import make_optimizer
from repro.train import Trainer, init_train_state, make_train_step, \
    zeno_scores

M, NBYZ = 10, 4
BYZ = jnp.arange(M) < NBYZ


@pytest.fixture(scope="module")
def task():
    return tasks.make_teacher_task()


def run(task, attack_name, defense, steps=120, reset_period=0):
    attacks = atk_lib.make_registry(delay=16)
    attack = attacks[attack_name]
    opt = make_optimizer(TrainConfig(lr=0.1))
    params = tasks.student_init(task)
    sg_cfg, aggregator, held = None, None, None
    if defense.startswith("safeguard"):
        sg_cfg = SafeguardConfig(m=M, T0=20, T1=60, threshold_floor=0.1,
                                 reset_period=reset_period)
    else:
        aggregator = agg_lib.make_registry(NBYZ, M)[defense]
        if aggregator.needs_scores:
            held = tasks.teacher_batches(task, 10, seed=99)
    state = init_train_state(params, opt, sg_cfg=sg_cfg, attack=attack)
    step = make_train_step(tasks.mlp_loss, opt, byz_mask=BYZ,
                           sg_cfg=sg_cfg, aggregator=aggregator,
                           attack=attack)
    flip = BYZ if attack.data_attack else None
    it = tasks.teacher_batches(task, 100, m=M, flip_mask=flip)
    tr = Trainer(state, step, it, held_iter=held, log_every=10 ** 9,
                 name="t")
    tr.run(steps, verbose=False)
    eval_b = tasks.teacher_batch(task, jax.random.PRNGKey(123), 2000)
    return tr.state, float(tasks.mlp_accuracy(tr.state.params, eval_b))


def test_safeguard_beats_mean_under_sign_flip(task):
    st_sg, acc_sg = run(task, "sign_flip", "safeguard")
    st_mean, acc_mean = run(task, "sign_flip", "mean")
    assert acc_sg > acc_mean + 0.05
    assert bool((~st_sg.sg_state.good[:NBYZ]).all())        # caught
    assert bool(st_sg.sg_state.good[NBYZ:].all())           # honest kept


def test_safeguard_harmless_without_attack(task):
    st, acc = run(task, "none", "safeguard")
    assert bool(st.sg_state.good.all())
    assert acc > 0.5


def test_label_flip_attack_mild(task):
    """Paper: label flipping is weak — safeguard converges fine (and need
    not catch anyone)."""
    _, acc = run(task, "label_flip", "safeguard")
    assert acc > 0.5


def test_zeno_runs_with_held_batch(task):
    _, acc = run(task, "sign_flip", "zeno", steps=60)
    assert acc > 0.2


def test_baselines_run(task):
    for d in ("coord_median", "geo_median", "krum", "trimmed_mean"):
        _, acc = run(task, "none", d, steps=40)
        assert 0.0 <= acc <= 1.0


def test_variance_attack_breaks_coord_median_not_safeguard(task):
    """The paper's headline: the variance attack defeats historyless
    defenses while the safeguard retains accuracy."""
    _, acc_cm = run(task, "variance", "coord_median", steps=150)
    _, acc_sg = run(task, "variance", "safeguard", steps=150)
    assert acc_sg >= acc_cm - 0.02


def test_zeno_scores_sign():
    task = tasks.make_teacher_task(d_in=8, d_hidden=16, n_classes=3)
    params = tasks.student_init(task)
    held = tasks.teacher_batch(task, jax.random.PRNGKey(5), 256)
    g_good = jax.grad(tasks.mlp_loss)(params, held)
    g_bad = jax.tree.map(jnp.negative, g_good)
    grads = jax.tree.map(lambda a, b: jnp.stack([a, b]), g_good, g_bad)
    scores = zeno_scores(tasks.mlp_loss, params, grads, held, eta=0.1,
                         rho=0.0)
    assert float(scores[0]) > float(scores[1])


def test_adaptive_attack_state_threads_through_trainer(task):
    """The feedback loop closes through the Trainer path too: the
    adaptive controller state moves away from its init (observe absorbed
    the safeguard's public outputs) and survives as the scan/vmap-stable
    scalar pytree."""
    st, acc = run(task, "adaptive_flip", "safeguard", steps=30)
    assert st.attack_state["aggr"].shape == ()
    assert float(st.attack_state["aggr"]) != pytest.approx(1.2)  # moved
    # ...and against a filterless baseline it ramps to the cap
    st, _ = run(task, "adaptive_flip", "mean", steps=60)
    assert float(st.attack_state["aggr"]) == pytest.approx(4.0)


def test_transient_failure_recovery(task):
    """Section 5 / Figure 2(b): with periodic reset, a worker that fails
    transiently is readmitted and contributes again."""
    st, acc = run(task, "none", "safeguard", reset_period=40)
    assert bool(st.sg_state.good.all())
    assert acc > 0.5
