"""Known-bad fixture: `host-cast` — float() on a traced value inside a
trace body concretizes the tracer."""


def make_loss():
    def step_fn(params, batch):
        scale = float(params["w"])         # BAD: host cast of a tracer
        return scale * batch
    return step_fn
