"""Known-good fixture: host-callback exemption.  Functions handed to
``jax.experimental.io_callback`` / ``jax.debug.callback`` execute on
the HOST — numpy, ``float()`` and file-ish work on their arguments are
legal there, and the analyzer must not flag them even though the
callback is defined inside a trace body (the live-telemetry tap shape,
DESIGN.md §17)."""
import jax
import numpy as np
from jax.experimental import io_callback


def make_step():
    def step_fn(state, batch):
        def tap(payload):
            # OK: host context — np/float on callback arguments
            return float(np.mean(payload["loss"]))

        io_callback(tap, None, {"loss": state})
        jax.debug.callback(lambda v: print(int(np.asarray(v))), state)
        return state, batch
    return step_fn
