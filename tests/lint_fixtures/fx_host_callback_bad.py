"""Known-bad fixture: the host-callback exemption must not leak.  The
``tap`` body is host context (exempt, see fx_host_callback_good.py),
but numpy on a traced value in the surrounding trace body — right next
to the ``io_callback`` — still fires ``np-in-trace``."""
import numpy as np
from jax.experimental import io_callback


def make_step():
    def step_fn(state, batch):
        def tap(payload):
            return None

        io_callback(tap, None, {"loss": state})
        bad = np.sum(state)                # BAD: numpy on a tracer
        return bad, batch
    return step_fn
