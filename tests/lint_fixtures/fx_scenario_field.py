"""Known-bad fixture: `scenario-hash` — a Scenario grown by a field
(`new_knob`) whose hash treatment is not declared in the committed
baseline (scenario_fields_baseline.json next to this file declares only
`attack`/`steps`)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class Scenario:
    attack: str
    steps: int = 100
    new_knob: float = 0.5                  # BAD: undeclared field
