"""Known-bad fixture: `knob-literal` — a knob-named parameter defaulted
to a bare literal instead of DEFENSE_DEFAULTS/ADAPTIVE_DEFAULTS."""


def make_clipper(m, clip_tau=1.0):         # BAD: duplicated knob literal
    return m, clip_tau
