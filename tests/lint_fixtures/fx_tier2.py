"""Known-bad tier-2 fixtures, traced by tests/test_lint.py with
jax.make_jaxpr: `sqrt-diff` (unclamped sqrt of a subtraction — the PR-3
NaN class) and `f64` (a float64 promotion under x64)."""
import jax.numpy as jnp


def unclamped_dist(x, y):
    return jnp.sqrt(x - y)                 # BAD: no maximum(..., 0.0)


def clamped_dist(x, y):
    return jnp.sqrt(jnp.maximum(x - y, 0.0))


def promotes_f64(x):
    return x.astype("float64") * 2.0       # BAD: x64 in the trace
