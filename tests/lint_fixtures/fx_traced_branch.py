"""Known-bad fixture: `traced-branch` — a Python `if` on a traced value
inside a jit body bakes the branch at trace time."""
import jax


def make_step():
    def step_fn(state, grads):
        if grads > 0:                      # BAD: traced condition
            state = state + grads
        return state
    return jax.jit(step_fn)
