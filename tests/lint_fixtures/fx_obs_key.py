"""Known-bad fixture: `obs-key` — an info key written but not
registered in repro.obs.schema (SchemaError at trace time)."""


def make_agg():
    def aggregate(state, grads, ctx):
        info = {"good": None,
                "totally_novel_stat": grads}   # BAD: unregistered key
        return grads, state, info
    return aggregate
