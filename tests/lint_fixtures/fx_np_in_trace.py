"""Known-bad fixture: `np-in-trace` — numpy called on a traced value
inside a trace body materializes the tracer."""
import numpy as np


def make_agg():
    def aggregate(state, grads, ctx):
        total = np.sum(grads)              # BAD: numpy on a tracer
        return total, state, {}
    return aggregate
