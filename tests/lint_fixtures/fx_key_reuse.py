"""Known-bad fixture: `key-reuse` — one rng key consumed by two
samplers in the same scope (correlated draws; stream contract)."""
import jax


def sample_pair(key):
    a = jax.random.normal(key, (3,))
    b = jax.random.normal(key, (3,))       # BAD: same key again
    return a, b
