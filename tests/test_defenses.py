"""The unified Defense protocol (DESIGN.md §12): registry contract,
bit-identical ports of the legacy aggregators and the safeguard, the
history-aware zoo (centered clipping, norm filter, DnC, composition),
the Weiszfeld numerics fixes, and the single-source trim derivation."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SafeguardConfig
from repro.core import aggregators as agg_lib
from repro.core import attacks as atk_lib
from repro.core import defenses as dfn
from repro.core import safeguard as sg

M, NBYZ = 10, 4
BYZ = np.arange(M) < NBYZ


@pytest.fixture
def grads(rng):
    k1, k2 = jax.random.split(rng)
    return {"a": jax.random.normal(k1, (M, 7, 4)),
            "b": jax.random.normal(k2, (M, 7))}


def params_like(grads):
    return jax.tree.map(lambda l: l[0], grads)


def run_defense(d: dfn.Defense, grads, ctx=None, state="init"):
    if state == "init":
        state = d.init_state(params_like(grads)) if d.init_state else None
    return d.aggregate(state, grads, ctx or {})


# -------------------------------------------------------------- protocol


def test_registry_contract(grads):
    """Every registry defense aggregates to a finite parameter pytree and
    publishes the mandatory good/n_good info keys."""
    reg = dfn.make_registry(M, NBYZ)
    assert set(reg) >= {"mean", "coord_median", "trimmed_mean",
                        "geo_median", "weiszfeld", "krum", "zeno",
                        "safeguard_single", "safeguard_double",
                        "centered_clip", "norm_filter", "dnc",
                        "safeguard_cclip"}
    for name, d in reg.items():
        # the trainer's ctx always carries the step rng (bucketing's
        # permutation draws from it)
        ctx = {"rng": jax.random.PRNGKey(11)}
        if d.needs_held_batch:
            ctx["scores"] = jnp.arange(M, dtype=jnp.float32)
        agg, state, info = run_defense(d, grads, ctx)
        assert agg["a"].shape == (7, 4), name
        assert bool(jnp.isfinite(agg["a"]).all()), name
        assert info["good"].shape == (M,) and info["good"].dtype == bool, name
        assert float(info["n_good"]) >= 1, name
        assert (state is None) == (not d.stateful), name


def test_stateless_ports_bit_identical(grads):
    """The seven historyless aggregators under the protocol return the
    exact bits of the pure functions they wrap."""
    reg = dfn.make_registry(M, NBYZ)
    trim = dfn.derive_trim(NBYZ, M)
    scores = jnp.linspace(-1.0, 1.0, M)
    pure = {
        "mean": agg_lib.mean(grads),
        "coord_median": agg_lib.coordinate_median(grads),
        "trimmed_mean": agg_lib.trimmed_mean(grads, trim=trim),
        "geo_median": agg_lib.geometric_medoid(grads),
        "weiszfeld": agg_lib.geometric_median(grads),
        "krum": agg_lib.krum(grads, n_byz=NBYZ),
        "zeno": agg_lib.zeno(grads, scores, n_byz=NBYZ),
    }
    for name, want in pure.items():
        got, _, _ = run_defense(reg[name], grads, {"scores": scores})
        for g, w in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(want)):
            assert np.array_equal(np.asarray(g), np.asarray(w)), name


def test_safeguard_port_bit_identical(grads):
    """The safeguard Defense is the plain safeguard_step: same aggregate,
    same state, same info, step for step."""
    cfg = SafeguardConfig(m=M, T0=4, T1=8, threshold_floor=0.5)
    d = dfn.make_safeguard_defense(cfg)
    st_d = d.init_state(params_like(grads))
    st_s = sg.init_state(cfg, params_like(grads))
    for t in range(6):
        g = jax.tree.map(lambda l: l + 0.1 * t, grads)
        agg_d, st_d, info_d = d.aggregate(st_d, g, {})
        st_s, agg_s, info_s = sg.safeguard_step(st_s, g, cfg)
        assert np.array_equal(np.asarray(st_d.good), np.asarray(st_s.good))
        assert np.array_equal(np.asarray(st_d.B), np.asarray(st_s.B))
        for a, b in zip(jax.tree_util.tree_leaves(agg_d),
                        jax.tree_util.tree_leaves(agg_s)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert np.array_equal(np.asarray(info_d["dist_to_med_B"]),
                              np.asarray(info_s["dist_to_med_B"]))


def test_zeno_requires_scores(grads):
    reg = dfn.make_registry(M, NBYZ)
    with pytest.raises(ValueError, match="scores"):
        run_defense(reg["zeno"], grads, {})


def test_final_good_extraction(grads):
    reg = dfn.make_registry(M, NBYZ)
    assert dfn.final_good(None) is None
    for name in ("mean", "centered_clip"):
        _, state, _ = run_defense(reg[name], grads)
        assert dfn.final_good(state) is None
    for name in ("safeguard_double", "norm_filter", "dnc",
                 "safeguard_cclip"):
        _, state, _ = run_defense(reg[name], grads)
        good = dfn.final_good(state)
        assert good is not None and good.shape == (M,), name


def test_trim_derivation_single_source():
    """Satellite: the legacy aggregator registry and the Defense registry
    share one trim/n_byz derivation (defenses.derive_trim)."""
    for m, b in ((10, 4), (9, 7), (5, 1)):
        want = dfn.derive_trim(b, m)
        a = agg_lib.make_registry(b, m)["trimmed_mean"]
        d = dfn.make_registry(m, b)["trimmed_mean"]
        g = {"w": jnp.arange(m * 3, dtype=jnp.float32).reshape(m, 3)}
        np.testing.assert_array_equal(
            np.asarray(a.fn(g)["w"]),
            np.asarray(agg_lib.trimmed_mean(g, trim=want)["w"]))
        got, _, _ = d.aggregate(None, g, {})
        np.testing.assert_array_equal(
            np.asarray(got["w"]),
            np.asarray(agg_lib.trimmed_mean(g, trim=want)["w"]))
    assert dfn.static_nbyz_names() == {"trimmed_mean", "krum", "zeno",
                                       "bucketing_krum"}


# ------------------------------------------------------------- weiszfeld


def test_weiszfeld_convergence_regression(rng):
    """Satellite: Weiszfeld converges to the true geometric median (checked
    against a long-run numpy fixed point) and keeps improving with more
    iterations — the f32-carried iterate regression."""
    arr = np.asarray(jax.random.normal(rng, (M, 6)), np.float64)
    arr[:3] += 25.0                          # outlier cluster
    y = arr.mean(0)
    for _ in range(4096):                    # numpy oracle fixed point
        d = np.sqrt(((arr - y[None]) ** 2).sum(1) + 1e-8)
        w = 1.0 / d
        y = (w[:, None] * arr).sum(0) / w.sum()

    g = {"w": jnp.asarray(arr, jnp.float32)}
    got8 = np.asarray(agg_lib.geometric_median(g, iters=8)["w"])
    got64 = np.asarray(agg_lib.geometric_median(g, iters=64)["w"])
    assert np.linalg.norm(got64 - y) < 1e-2
    assert np.linalg.norm(got64 - y) <= np.linalg.norm(got8 - y) + 1e-5


def test_weiszfeld_f32_iterate_under_low_precision(rng):
    """bf16 gradients: the iterate must be carried in f32 (a per-step
    bf16 round trip stalls at the quantization grid)."""
    arr = jax.random.normal(rng, (M, 16))
    g16 = {"w": arr.astype(jnp.bfloat16)}
    got = agg_lib.geometric_median(g16, iters=32)
    assert got["w"].dtype == jnp.bfloat16       # interface dtype preserved
    want = agg_lib.geometric_median(
        {"w": arr.astype(jnp.bfloat16).astype(jnp.float32)}, iters=32)
    # identical up to the single final cast — NOT 32 accumulated casts
    np.testing.assert_allclose(
        np.asarray(got["w"], np.float32), np.asarray(want["w"]),
        atol=float(jnp.finfo(jnp.bfloat16).eps) * 4)


def test_weiszfeld_degenerate_weights_no_nan():
    """w.sum() == 0 guard: inputs whose pairwise distances overflow f32
    (every weight underflows to 0) must not return NaN."""
    g = {"w": jnp.full((6, 8), 1e25, jnp.float32)
         * (1.0 + jnp.arange(6, dtype=jnp.float32))[:, None]}
    out = agg_lib.geometric_median(g, iters=8)
    assert bool(jnp.isfinite(out["w"]).all())


# ----------------------------------------------------------------- zoo


def _byz_variance_stack(key, m=M, n_byz=NBYZ, d=64, z=1.5):
    """Honest rows ~ N(mu, I); byzantine rows collude on mu - z*sigma."""
    byz = jnp.arange(m) < n_byz
    g = {"w": 2.0 + jax.random.normal(key, (m, d))}
    out, _ = atk_lib.make_variance_attack(z)(g, byz, None, jnp.int32(0),
                                             key)
    return out, byz


def test_centered_clip_bounds_byzantine_influence(rng):
    """A colluding row at huge magnitude moves the aggregate by at most
    the clip radius — the bounded-influence property mean lacks."""
    d = dfn.make_centered_clip(M, tau=1.0, beta=0.0)
    g = {"w": jax.random.normal(rng, (M, 32))}
    g_adv = {"w": g["w"].at[:NBYZ].set(1e4)}
    state = d.init_state(params_like(g))
    agg_clean, _, _ = d.aggregate(state, g, {})
    agg_adv, _, _ = d.aggregate(state, g_adv, {})
    honest_scale = float(jnp.linalg.norm(g["w"][NBYZ:].mean(0)))
    shift = float(jnp.linalg.norm(agg_adv["w"] - agg_clean["w"]))
    assert shift < 10.0 * honest_scale + 10.0      # nothing like 1e4
    assert bool(jnp.isfinite(agg_adv["w"]).all())


def test_centered_clip_momentum_is_history(rng):
    """The momentum buffer carries history: the same gradients through a
    fresh state and a warmed state aggregate differently."""
    d = dfn.make_centered_clip(M, beta=0.9)
    g = {"w": jax.random.normal(rng, (M, 16))}
    fresh = d.init_state(params_like(g))
    _, warmed, _ = d.aggregate(fresh, g, {})
    a1, _, _ = d.aggregate(fresh, {"w": -g["w"]}, {})
    a2, _, _ = d.aggregate(warmed, {"w": -g["w"]}, {})
    assert not np.allclose(np.asarray(a1["w"]), np.asarray(a2["w"]))


def test_norm_filter_rejects_spike_against_ema(rng):
    """A norm spike in step 2 is rejected against the EMA of step 1's
    honest scale — the history the defense carries."""
    d = dfn.make_norm_filter(M, mult=2.0, ema_beta=0.9)
    g = {"w": jax.random.normal(rng, (M, 32))}
    state = d.init_state(params_like(g))
    _, state, info1 = d.aggregate(state, g, {})
    assert bool(info1["good"].all())               # calibration step
    spike = {"w": g["w"].at[:NBYZ].mul(50.0)}
    agg, state, info2 = d.aggregate(state, spike, {})
    assert not bool(info2["good"][:NBYZ].any())    # spikes rejected
    assert bool(info2["good"][NBYZ:].all())        # honest kept
    assert np.array_equal(np.asarray(dfn.final_good(state)),
                          np.asarray(info2["good"]))


def test_dnc_finds_variance_colluders(rng):
    """The variance attack is invisible per coordinate but IS the top
    singular direction of the centered stack — DnC removes exactly the
    colluders."""
    d = dfn.make_dnc(M, NBYZ, iters=8)
    g, byz = _byz_variance_stack(rng)
    state = d.init_state(params_like(g))
    # two steps: the warm-started direction sharpens the second decision
    _, state, _ = d.aggregate(state, g, {})
    g2, _ = _byz_variance_stack(jax.random.fold_in(rng, 1))
    _, state, info = d.aggregate(state, g2, {})
    assert not bool(info["good"][:NBYZ].any())     # colluders dropped
    assert bool(info["good"][NBYZ:].all())


def test_dnc_nbyz_zero_keeps_everyone(rng):
    d = dfn.make_dnc(M, 0, iters=4)
    g = {"w": jax.random.normal(rng, (M, 16))}
    _, _, info = run_defense(d, g)
    assert bool(info["good"].all())


def test_safeguard_cclip_filters_like_safeguard(rng):
    """The composition's good-set trajectory is the safeguard's own
    (same windows/thresholds), while the aggregate is the clipped
    center, not the masked mean."""
    cfg = SafeguardConfig(m=M, T0=4, T1=8, threshold_floor=0.1)
    comp = dfn.make_safeguard_cclip(cfg)
    plain = dfn.make_safeguard_defense(cfg)
    key = rng
    st_c = comp.init_state({"w": jnp.zeros((12,))})
    st_p = plain.init_state({"w": jnp.zeros((12,))})
    for t in range(10):
        key, k = jax.random.split(key)
        g = {"w": 1.0 + 0.05 * jax.random.normal(k, (M, 12))}
        g["w"] = g["w"].at[:NBYZ].multiply(-1.0)   # sign flip colluders
        agg_c, st_c, info_c = comp.aggregate(st_c, g, {})
        agg_p, st_p, info_p = plain.aggregate(st_p, g, {})
        assert np.array_equal(np.asarray(info_c["good"]),
                              np.asarray(info_p["good"]))
    assert not bool(dfn.final_good(st_c)[:NBYZ].any())   # flippers evicted
    assert bool(dfn.final_good(st_c)[NBYZ:].all())
    assert not np.allclose(np.asarray(agg_c["w"]), np.asarray(agg_p["w"]))


def test_safeguard_cclip_requires_flat_engine():
    with pytest.raises(ValueError, match="flat"):
        dfn.make_safeguard_cclip(
            SafeguardConfig(m=M, engine="stacked"))


def test_flat_state_defenses_scan_and_vmap(rng):
    """Zoo states are plain fixed-shape pytrees: a 3-step lax.scan over a
    vmapped (2-lane) aggregate runs and stays finite — the property the
    campaign engine relies on."""
    reg = dfn.make_registry(M, NBYZ)
    for name in ("centered_clip", "norm_filter", "dnc", "safeguard_cclip"):
        d = reg[name]
        g = {"w": jax.random.normal(rng, (2, M, 24))}    # 2 lanes
        state0 = jax.vmap(lambda _: d.init_state({"w": jnp.zeros((24,))})
                          )(jnp.arange(2))

        def body(state, t):
            agg, state, info = jax.vmap(
                lambda s, gl: d.aggregate(s, {"w": gl + 0.1 * t}, {})
            )(state, g["w"])
            return state, agg["w"]

        _, stacked = jax.lax.scan(body, state0, jnp.arange(3))
        assert stacked.shape == (3, 2, 24), name
        assert bool(jnp.isfinite(stacked).all()), name


def test_defense_feedback_projection(grads):
    """Filtering zoo defenses surface their evictions to adaptive
    attacks; pure aggregation reduces to null feedback exactly."""
    reg = dfn.make_registry(M, NBYZ)
    _, _, info_mean = run_defense(reg["mean"], grads)
    fb = atk_lib.defense_feedback(info_mean, M)
    null = atk_lib.null_feedback(M)
    for k in null:
        assert np.array_equal(np.asarray(fb[k]), np.asarray(null[k])), k

    d = dfn.make_norm_filter(M)
    state = d.init_state(params_like(grads))
    _, state, _ = d.aggregate(state, grads, {})
    spike = jax.tree.map(lambda l: l.at[:NBYZ].mul(50.0), grads)
    _, _, info = d.aggregate(state, spike, {})
    fb = atk_lib.defense_feedback(info, M)
    assert not bool(fb["good"][:NBYZ].any())
    assert float(fb["n_good"]) == M - NBYZ
    _, _, info_sg = run_defense(reg["safeguard_double"], grads)
    fb_sg = atk_lib.defense_feedback(info_sg, M)
    assert float(fb_sg["threshold_B"] if "threshold_B" in fb_sg else
                 fb_sg["threshold"]) < atk_lib.OPEN_LOOP_THRESHOLD


# ------------------------------------------------------------- bucketing


def test_bucketing_registry_and_factory_validation():
    reg = dfn.make_registry(M, NBYZ)
    assert "bucketing_krum" in reg and "bucketing_cclip" in reg
    assert reg["bucketing_krum"].static_nbyz      # inner krum slices on b
    assert not reg["bucketing_cclip"].static_nbyz
    with pytest.raises(ValueError, match="not divisible"):
        dfn.make_bucketing(reg["mean"], M, 3)
    with pytest.raises(ValueError, match="held-batch"):
        dfn.make_bucketing(reg["zeno"], M, 2)
    # a traced n_byz keeps the name resolvable but refuses aggregation
    traced = dfn.make_registry(M, jnp.asarray(NBYZ))["bucketing_krum"]
    with pytest.raises(ValueError, match="statically"):
        traced.aggregate(None, {"a": jnp.zeros((M, 2))},
                         {"rng": jax.random.PRNGKey(0)})


def test_bucketing_needs_step_rng(grads):
    d = dfn.make_registry(M, NBYZ)["bucketing_krum"]
    with pytest.raises(ValueError, match="rng"):
        run_defense(d, grads, ctx={})


def test_derive_bucket_nbyz():
    # ceil(b/s) corrupt buckets — never capped
    assert dfn.derive_bucket_nbyz(4, 2) == 2
    assert dfn.derive_bucket_nbyz(3, 2) == 2
    assert dfn.derive_bucket_nbyz(0, 2) == 0
    assert dfn.derive_bucket_nbyz(4, 1) == 4
    # a combination inner Krum cannot tolerate is OMITTED from the
    # registry (like the sketched safeguard_cclip), never run with a
    # silently understated budget
    reg = dfn.make_registry(6, 4)       # 3 buckets, ceil(4/2)=2 > 0
    assert "bucketing_krum" not in reg
    assert "bucketing_cclip" in reg     # clipping has no budget bound


def test_bucketing_mean_is_permutation_invariant_mean(rng):
    """Bucket means of a permutation, averaged by an inner mean, is the
    global mean — the meta-defense is exact on the trivial inner rule."""
    g = {"a": jax.random.normal(rng, (M, 5))}
    inner = dfn.make_registry(M // 2, 0)["mean"]
    d = dfn.make_bucketing(inner, M, 2)
    agg, _, info = run_defense(d, g, ctx={"rng": jax.random.PRNGKey(3)})
    np.testing.assert_allclose(np.asarray(agg["a"]),
                               np.asarray(g["a"]).mean(axis=0),
                               rtol=1e-5, atol=1e-6)
    assert info["n_good"] == M and bool(info["good"].all())


def test_bucketing_maps_bucket_decisions_to_workers():
    """A bucket rejected by a *filtering* inner rule marks exactly its s
    workers not-good on the (m,) surface the trainer/attacks observe."""
    s = 2
    inner = dfn.make_norm_filter(M // s, mult=2.0)
    d = dfn.make_bucketing(inner, M, s)
    # one wildly deviating worker: whatever bucket the permutation puts
    # it in has a huge mean norm and fails the inner norm filter
    g = {"a": jnp.ones((M, 6)).at[0].set(1e6)}
    state = d.init_state(params_like(g))
    _, state, _ = d.aggregate(state, g, {"rng": jax.random.PRNGKey(5)})
    _, _, info = d.aggregate(state, g, {"rng": jax.random.PRNGKey(6)})
    good = np.asarray(info["good"])
    assert good.shape == (M,)
    assert good.sum() == M - s                    # exactly one bucket lost
    assert not good[0]                            # ... the deviator's
    assert float(info["n_good"]) == M - s
    assert np.asarray(info["bucket_good"]).shape == (M // s,)
    assert np.asarray(info["bucket_good"]).sum() == M // s - 1


def test_bucketing_cclip_state_is_bucket_shaped(rng):
    d = dfn.make_registry(M, NBYZ)["bucketing_cclip"]
    g = {"a": jax.random.normal(rng, (M, 9))}
    state = d.init_state(params_like(g))
    assert state["momentum"].shape[0] == M // 2
    agg, state2, info = run_defense(d, g,
                                    ctx={"rng": jax.random.PRNGKey(7)})
    assert state2["momentum"].shape == state["momentum"].shape
    assert info["n_good"] == M                    # clipping evicts nobody


def test_threshold_scale_knob_relaxes_empirical_filter(rng):
    """The eviction multiplier is a registry knob (vmap axis in the
    campaign): a tiny scale evicts an outlier the default keeps."""
    k1, k2 = jax.random.split(rng)
    g = {"a": jax.random.normal(k1, (M, 8))}
    g["a"] = g["a"].at[M - 1].add(2.0)            # mild honest outlier
    def run_scale(scale):
        d = dfn.make_registry(M, NBYZ, T0=1, T1=1,
                              threshold_scale=scale)["safeguard_double"]
        st = d.init_state(params_like(g))
        for _ in range(3):
            _, st, info = d.aggregate(st, g, {"rng": k2})
        return int(np.asarray(info["good"]).sum())
    assert run_scale(1e-3) < run_scale(1e3)
