"""Campaign subsystem (DESIGN.md §10): scenario hashing, grid expansion,
batch grouping, engine-vs-Trainer equivalence (bit-for-bit), stateful
attacks under vmap, knob-axis batching, and the resumable store."""

import dataclasses
import json
import os

import numpy as np
import pytest

from repro.campaign import engine
from repro.campaign import run as campaign_run
from repro.campaign.scenario import (Scenario, expand_grid, scenario_id,
                                     with_seeds)
from repro.campaign.store import CampaignStore
from repro.data import tasks
from benchmarks import common
from benchmarks import table1_attack_grid


# ---------------------------------------------------------------- scenario


def test_scenario_id_stable_and_unique():
    a = Scenario(attack="sign_flip", defense="mean")
    b = Scenario(attack="sign_flip", defense="mean")
    assert scenario_id(a) == scenario_id(b)
    ids = {scenario_id(s) for s in (
        a,
        dataclasses.replace(a, seed=1),
        dataclasses.replace(a, threshold_floor=0.2),
        dataclasses.replace(a, attack="variance"),
        dataclasses.replace(a, n_byz=3),
    )}
    assert len(ids) == 5


def test_scenario_id_excludes_default_valued_fields():
    """The store key hashes only non-default fields, so growing Scenario
    by a defaulted knob later does not orphan previously stored cells."""
    import hashlib
    s = Scenario(attack="a", defense="d", steps=99)
    expect = hashlib.sha256(json.dumps(
        {"attack": "a", "defense": "d", "steps": 99},
        sort_keys=True).encode()).hexdigest()[:16]
    assert scenario_id(s) == expect


def test_scenario_id_folds_variance_calibration():
    """The variance attack's collusion strength is part of every variance
    cell's store key: recalibrating attacks.VARIANCE_Z orphans exactly
    the stale variance rows instead of silently mixing strengths in a
    resumed store.  Non-variance keys are untouched."""
    import hashlib
    from repro.core.attacks import VARIANCE_Z
    v = Scenario(attack="variance", defense="mean")
    want = hashlib.sha256(json.dumps(
        {"_variance_z": VARIANCE_Z, "attack": "variance",
         "defense": "mean"}, sort_keys=True).encode()).hexdigest()[:16]
    assert scenario_id(v) == want
    s = Scenario(attack="sign_flip", defense="mean")
    want = hashlib.sha256(json.dumps(
        {"attack": "sign_flip", "defense": "mean"},
        sort_keys=True).encode()).hexdigest()[:16]
    assert scenario_id(s) == want


def test_spectral_iters_over_cap_fails_loudly():
    """A spectral_iters above the static scan length would silently
    truncate (lanes above the cap would be bit-identical to the cap) —
    both the engine and the factory reject it."""
    from repro.core import defenses as dfn
    scns = [Scenario(attack="variance", defense="dnc",
                     spectral_iters=dfn.MAX_SPECTRAL_ITERS + 1)]
    with pytest.raises(ValueError, match="truncate"):
        engine.stack_knobs(scns)
    with pytest.raises(ValueError, match="truncate"):
        dfn.make_dnc(10, 4, iters=dfn.MAX_SPECTRAL_ITERS + 1)


def test_expand_grid_and_seeds():
    grid = expand_grid(attack=["a1", "a2"], defense=["d1", "d2", "d3"])
    assert len(grid) == 6
    assert grid[0].attack == "a1" and grid[0].defense == "d1"
    seeded = with_seeds(grid, 4)
    assert len(seeded) == 24
    assert sorted({s.seed for s in seeded}) == [0, 1, 2, 3]
    with pytest.raises(ValueError):
        expand_grid(attack=["a"], defense=["d"], not_a_field=[1])


def test_batch_key_grouping():
    scns = (
        # scale variants + seeds of one family/defense -> one group
        [Scenario(attack=a, defense="safeguard_double", seed=k)
         for a in ("safeguard_x0.6", "safeguard_x0.7") for k in (0, 1)]
        # floor variants batch for safeguard defenses
        + [Scenario(attack="safeguard_x0.6", defense="safeguard_double",
                    threshold_floor=0.5)]
        # different defense -> own group
        + [Scenario(attack="safeguard_x0.6", defense="mean")]
        # krum consumes n_byz statically -> one group per n_byz
        + [Scenario(attack="sign_flip", defense="krum", n_byz=b)
           for b in (3, 4)]
        # n_byz is a vmap knob for coord_median -> one group
        + [Scenario(attack="sign_flip", defense="coord_median", n_byz=b)
           for b in (3, 4)]
    )
    groups = engine.group_scenarios(scns)
    assert [len(g) for g in groups] == [5, 1, 1, 1, 2]


# ---------------------------------------------------------------- engine


STEPS = 30


def test_engine_matches_trainer_path():
    """Acceptance: vmapped engine trajectories == the per-trial Trainer
    path, numerically identical (same rng streams, same op order) — for
    EVERY ported defense of the protocol registry (all seven historyless
    aggregators, both safeguard variants, the stateful zoo)."""
    task = tasks.make_teacher_task()
    for attack, defense in [("sign_flip", "safeguard_double"),
                            ("variance", "safeguard_single"),
                            ("variance", "coord_median"),
                            ("sign_flip", "mean"),
                            ("variance", "trimmed_mean"),
                            ("sign_flip", "geo_median"),
                            ("variance", "weiszfeld"),
                            ("label_flip", "krum"),
                            ("sign_flip", "zeno"),
                            # adaptive: registry and Scenario share the
                            # ADAPTIVE_DEFAULTS single source, so the two
                            # paths must build identical attacks
                            ("adaptive_flip", "safeguard_double"),
                            ("adaptive_variance", "safeguard_double"),
                            ("oscillating", "safeguard_double"),
                            ("median_capture", "safeguard_double")]:
        scn = common.scenario_for(attack, defense, steps=STEPS, task=task)
        eng = engine.run_scenarios([scn])[scenario_id(scn)]
        loop = common.run_experiment_loop(task, attack, defense,
                                          steps=STEPS)
        assert eng["acc"] == pytest.approx(loop["acc"], abs=1e-12), \
            (attack, defense)
        if "caught_byz" in loop:
            assert eng["caught_byz"] == loop["caught_byz"]
            assert eng["evicted_honest"] == loop["evicted_honest"]


def test_engine_matches_trainer_path_zoo():
    """The stateful zoo (DESIGN.md §12): registry and Scenario share the
    DEFENSE_DEFAULTS single source, so the two paths build identical
    defenses.  Equality is exact for three of the four; the
    safeguard+clip COMPOSITION is exact only up to ulp-level XLA fusion
    (the composed graph fuses differently inside ``lax.scan`` than as a
    standalone jitted step — filter decisions still match exactly;
    vmapped-vs-unbatched engine lanes stay bit-exact,
    ``test_stateful_zoo_defenses_vmap_bitexact``)."""
    task = tasks.make_teacher_task()
    for attack, defense, tol in [("variance", "centered_clip", 1e-12),
                                 ("sign_flip", "norm_filter", 1e-12),
                                 ("variance", "dnc", 1e-12),
                                 ("variance", "safeguard_cclip", 2e-3)]:
        scn = common.scenario_for(attack, defense, steps=STEPS, task=task)
        eng = engine.run_scenarios([scn])[scenario_id(scn)]
        loop = common.run_experiment_loop(task, attack, defense,
                                          steps=STEPS)
        assert eng["acc"] == pytest.approx(loop["acc"], abs=tol), \
            (attack, defense)
        if "caught_byz" in loop:
            assert eng["caught_byz"] == loop["caught_byz"], (attack, defense)
            assert eng["evicted_honest"] == loop["evicted_honest"]


def test_engine_matches_trainer_path_hetero_and_bucketing():
    """Acceptance: engine-vs-Trainer bit-identity holds for the hetero
    batch_fns (Dirichlet label skew, teacher-rotation shift — the
    iterator in repro.data.hetero shares the engine's key schedule and
    selection) and for bucketing-wrapped defenses (the permutation
    stream comes from the same scan-threaded rng on both paths)."""
    task = tasks.make_teacher_task()
    for attack, defense, hk in [
            ("none", "bucketing_krum", {}),
            ("variance", "bucketing_cclip", {}),
            ("none", "krum", dict(hetero="dirichlet", hetero_alpha=0.1)),
            ("variance", "safeguard_double",
             dict(hetero="dirichlet", hetero_alpha=0.1)),
            ("sign_flip", "mean", dict(hetero="shift", hetero_shift=1.0)),
            ("label_flip", "centered_clip",
             dict(hetero="dirichlet", hetero_alpha=0.3))]:
        scn = common.scenario_for(attack, defense, steps=STEPS, task=task,
                                  **hk)
        eng = engine.run_scenarios([scn])[scenario_id(scn)]
        loop = common.run_experiment_loop(task, attack, defense,
                                          steps=STEPS, **hk)
        assert eng["acc"] == pytest.approx(loop["acc"], abs=1e-12), \
            (attack, defense, hk)
        if "caught_byz" in loop:
            assert eng["caught_byz"] == loop["caught_byz"], (attack,
                                                             defense)
            assert eng["evicted_honest"] == loop["evicted_honest"]


def test_convex_attack_port_matches_legacy_loop():
    """Satellite: benchmarks/convex_attack.py now routes through the
    campaign engine — both its variants (the paper's windowed safeguard
    and the unwindowed convex-filter emulation, custom T0/T1/floor and
    an explicit burst window) reproduce the raw Trainer loop they
    replaced bit-for-bit, and the ported benchmark still shows the
    Appendix C.3 separation: windows catch the burst, the whole-history
    filter does not."""
    from benchmarks import convex_attack
    task = tasks.make_teacher_task()
    caught = {}
    for name, (t0, t1, floor) in convex_attack.VARIANTS.items():
        scn = convex_attack.variant_scenario(name, steps=120)
        eng = engine.run_scenarios([scn])[scenario_id(scn)]
        loop = common.run_experiment_loop(
            task, "burst", "safeguard_double", steps=120, batch=100,
            t0=t0, t1=t1, floor=floor,
            burst_start=convex_attack.BURST_START,
            burst_length=convex_attack.BURST_LENGTH)
        assert eng["acc"] == pytest.approx(loop["acc"], abs=1e-12), name
        assert eng["caught_byz"] == loop["caught_byz"], name
        assert eng["evicted_honest"] == loop["evicted_honest"], name
        caught[name] = eng["caught_byz"]
    assert caught["windowed"] == 4 and caught["unwindowed"] == 0


def test_stateful_attacks_vmap_bitexact():
    """Satellite: delayed/burst attack-state pytrees batch correctly over
    the seed axis — vmapped lanes match the unbatched trajectory
    bit-for-bit."""
    for attack in ("delayed", "burst"):
        scns = [Scenario(attack=attack, defense="safeguard_double",
                         steps=STEPS, seed=k, delay=8, burst_start=6,
                         burst_length=8) for k in range(3)]
        assert len(engine.group_scenarios(scns)) == 1
        batched = engine.run_scenarios(scns, batched=True)
        unbatched = engine.run_scenarios(scns, batched=False)
        for s in scns:
            b, u = batched[scenario_id(s)], unbatched[scenario_id(s)]
            for key in b["traces"]:
                assert np.array_equal(b["traces"][key], u["traces"][key]), \
                    (attack, s.seed, key)
            assert np.array_equal(b["final_good"], u["final_good"])
            assert b["acc"] == u["acc"]


def test_adaptive_attacks_vmap_bitexact():
    """Tentpole acceptance: feedback-coupled attack states (controller
    scalars updated from the previous step's safeguard outputs) batch
    correctly — vmapped lanes match the unbatched trajectory
    bit-for-bit."""
    for attack in ("adaptive_flip", "median_capture"):
        scns = [Scenario(attack=attack, defense="safeguard_double",
                         steps=STEPS, seed=k) for k in range(3)]
        assert len(engine.group_scenarios(scns)) == 1
        batched = engine.run_scenarios(scns, batched=True)
        unbatched = engine.run_scenarios(scns, batched=False)
        for s in scns:
            b, u = batched[scenario_id(s)], unbatched[scenario_id(s)]
            for key in b["traces"]:
                assert np.array_equal(b["traces"][key], u["traces"][key]), \
                    (attack, s.seed, key)
            assert np.array_equal(b["final_good"], u["final_good"])
            assert b["acc"] == u["acc"]


def test_stateful_zoo_defenses_vmap_bitexact():
    """Tentpole acceptance: the zoo defenses' state pytrees (momentum
    buffers, EMA scalars, warm-started spectral directions, composed
    safeguard accumulators) batch correctly over the seed axis —
    vmapped lanes match the unbatched trajectory bit-for-bit."""
    for defense in ("centered_clip", "norm_filter", "dnc",
                    "safeguard_cclip"):
        scns = [Scenario(attack="variance", defense=defense, steps=STEPS,
                         seed=k) for k in range(2)]
        assert len(engine.group_scenarios(scns)) == 1
        batched = engine.run_scenarios(scns, batched=True)
        unbatched = engine.run_scenarios(scns, batched=False)
        for s in scns:
            b, u = batched[scenario_id(s)], unbatched[scenario_id(s)]
            for key in b["traces"]:
                assert np.array_equal(b["traces"][key], u["traces"][key]), \
                    (defense, s.seed, key)
            assert b["acc"] == u["acc"], defense


def test_defense_knobs_are_vmap_axes():
    """clip_tau/clip_beta/spectral_iters only feed arithmetic inside
    Defense.aggregate, so all variants run as lanes of one program — and
    the traced knob changes the outcome."""
    scns = [Scenario(attack="variance", defense="centered_clip",
                     steps=STEPS, clip_tau=t, clip_beta=b)
            for t, b in ((0.5, 0.9), (3.0, 0.5))]
    assert len(engine.group_scenarios(scns)) == 1
    res = engine.run_scenarios(scns)
    a, b = (res[scenario_id(s)] for s in scns)
    assert not np.array_equal(a["traces"]["loss"], b["traces"]["loss"])

    scns = [Scenario(attack="variance", defense="dnc", steps=STEPS,
                     spectral_iters=i, n_byz=nb)
            for i, nb in ((1, 4), (8, 2))]
    assert len(engine.group_scenarios(scns)) == 1   # n_byz dynamic for dnc
    res = engine.run_scenarios(scns)
    a, b = (res[scenario_id(s)] for s in scns)
    assert not np.array_equal(a["traces"]["loss"], b["traces"]["loss"])
    assert a["caught_byz"] == 4 and b["caught_byz"] == 2


def test_centered_clip_survives_variance_attack_mean_does_not():
    """Acceptance: in the Table-1 grid protocol (150 steps, m=10,
    alpha=0.4), the variance attack measurably degrades the undefended
    mean while centered clipping — history via worker momentum and the
    carried center, nobody evicted — stays at the safeguard's level."""
    seeds = range(2)
    cells = {d: [Scenario(attack="variance", defense=d, steps=150, seed=k)
                 for k in seeds]
             for d in ("centered_clip", "mean", "safeguard_double")}
    res = engine.run_scenarios([s for scns in cells.values() for s in scns])

    def acc(d):
        return float(np.mean([res[scenario_id(s)]["acc"]
                              for s in cells[d]]))

    acc_cc, acc_mean, acc_sg = (acc(d) for d in
                                ("centered_clip", "mean",
                                 "safeguard_double"))
    assert acc_cc > acc_mean + 0.025          # mean degrades, cclip holds
    assert acc_cc >= acc_sg - 0.04            # at the safeguard's level
    for s in cells["centered_clip"]:          # bounded influence, no
        assert "caught_byz" not in res[scenario_id(s)]   # eviction at all


def test_adaptive_knobs_are_vmap_axes():
    """adapt_* controller knobs only feed arithmetic, so all variants run
    as lanes of one program — and the traced knob changes the outcome."""
    scns = [Scenario(attack="adaptive_flip", defense="safeguard_double",
                     steps=STEPS, adapt_target=t, adapt_rate=r)
            for t, r in ((0.6, 1.05), (0.9, 1.3))]
    assert len(engine.group_scenarios(scns)) == 1
    res = engine.run_scenarios(scns)
    a, b = (res[scenario_id(s)] for s in scns)
    assert not np.array_equal(a["traces"]["loss"], b["traces"]["loss"])


def test_threshold_tracker_under_filter_vs_no_defense():
    """Acceptance: the threshold-tracking flip hovers under the live
    eviction threshold (nobody evicted, accuracy within noise of the
    static safeguard rows) while the same attack destroys the no-defense
    baseline."""
    knobs = dict(adapt_init=0.0, adapt_rate=1.05, adapt_target=0.6)
    seeds = range(2)
    adaptive_sg = [Scenario(attack="adaptive_flip",
                            defense="safeguard_double", steps=40, seed=k,
                            **knobs) for k in seeds]
    adaptive_mean = [Scenario(attack="adaptive_flip", defense="mean",
                              steps=40, seed=k, **knobs) for k in seeds]
    static_sg = [Scenario(attack="safeguard_x0.6",
                          defense="safeguard_double", steps=40, seed=k)
                 for k in seeds]
    res = engine.run_scenarios(adaptive_sg + adaptive_mean + static_sg)

    for s in adaptive_sg:     # stays under the filter: nobody evicted
        assert res[scenario_id(s)]["caught_byz"] == 0, s.seed
        assert res[scenario_id(s)]["evicted_honest"] == 0, s.seed

    def acc_mean(scns):
        return float(np.mean([res[scenario_id(s)]["acc"] for s in scns]))

    sg_adaptive, sg_static = acc_mean(adaptive_sg), acc_mean(static_sg)
    no_defense = acc_mean(adaptive_mean)
    assert sg_adaptive > sg_static - 0.08     # within noise of static rows
    assert no_defense < 0.15                  # baseline driven to ~chance
    assert sg_adaptive - no_defense > 0.2


def test_burst_window_derives_from_trial_length():
    """Satellite: the default burst window follows the trial length, so a
    short (CI-scale) campaign still exercises the burst instead of
    silently benchmarking honest execution."""
    scn = Scenario(attack="burst", defense="safeguard_double", steps=STEPS)
    assert scn.burst_start == -1              # auto
    rec = engine.run_scenarios([scn])[scenario_id(scn)]
    assert rec["traces"]["caught_byz"].max() > 0   # the burst fired


def test_burst_that_cannot_fire_fails_loudly():
    scn = Scenario(attack="burst", defense="safeguard_double", steps=20,
                   burst_start=100)
    with pytest.raises(ValueError, match="never fire"):
        engine.run_scenarios([scn])


def test_threshold_floor_is_a_vmap_axis():
    """All safeguard-threshold variants run as lanes of one program, and
    the traced floor actually changes the filter decision."""
    scns = [Scenario(attack="sign_flip", defense="safeguard_single",
                     steps=STEPS, threshold_floor=f)
            for f in (0.1, 10 ** 6)]
    assert len(engine.group_scenarios(scns)) == 1
    res = engine.run_scenarios(scns)
    tight, huge = (res[scenario_id(s)] for s in scns)
    assert tight["caught_byz"] == 4          # sign-flippers evicted
    assert huge["caught_byz"] == 0           # threshold too lax to evict


def test_n_byz_is_a_vmap_axis_for_maskless_defenses():
    scns = [Scenario(attack="sign_flip", defense="coord_median",
                     steps=STEPS, n_byz=b) for b in (0, 4)]
    assert len(engine.group_scenarios(scns)) == 1
    res = engine.run_scenarios(scns)
    clean, attacked = (res[scenario_id(s)]["acc"] for s in scns)
    assert clean > attacked                  # alpha=0 trains strictly better


def test_trace_shapes():
    scn = Scenario(attack="none", defense="safeguard_double", steps=STEPS)
    rec = engine.run_scenarios([scn])[scenario_id(scn)]
    for key in ("loss", "n_good", "caught_byz"):
        assert rec["traces"][key].shape == (STEPS,)
    assert rec["traces"]["n_good"][-1] == 10.0


# ----------------------------------------------------------------- store


def test_store_resume_and_delta(tmp_path):
    argv = ["--campaign", "smoke", "--steps", "8", "--seeds", "1",
            "--root", str(tmp_path)]
    first = campaign_run.main(argv)
    assert (first["cells"], first["ran"]) == (4, 4)
    second = campaign_run.main(argv)
    assert second["ran"] == 0                # full resume: 0 new cells
    third = campaign_run.main(["--campaign", "smoke", "--steps", "8",
                               "--seeds", "2", "--root", str(tmp_path)])
    assert (third["cells"], third["ran"]) == (8, 4)   # only the delta


def test_store_tolerates_torn_line(tmp_path):
    store = CampaignStore("t", root=str(tmp_path))
    s = Scenario(attack="none", defense="mean")
    store.append(s, {"acc": 0.5, "traces": {"loss": np.zeros(3)}})
    with open(store.path, "a") as f:
        f.write('{"id": "torn')                       # killed mid-write
    records = store.load()
    assert set(records) == {scenario_id(s)}
    assert "traces" not in records[scenario_id(s)]["result"]
    assert store.pending([s, dataclasses.replace(s, seed=1)]) == \
        [dataclasses.replace(s, seed=1)]


def test_store_traces_opt_in(tmp_path):
    store = CampaignStore("t2", root=str(tmp_path))
    s = Scenario(attack="none", defense="mean")
    store.append(s, {"acc": 0.5,
                     "traces": {"loss": np.ones(2, np.float32)}},
                 store_traces=True)
    rec = store.load()[scenario_id(s)]
    # traces go to an .npz sidecar, not the JSONL: the record carries
    # only the pointer + field list (DESIGN.md §15)
    assert "traces" not in rec["result"]
    assert rec["result"]["trace_fields"] == ["loss"]
    sidecar = os.path.join(store.dir, rec["result"]["trace_file"])
    assert os.path.exists(sidecar)
    loaded = store.load_traces(scenario_id(s))
    assert loaded["loss"].dtype == np.float32         # dtype preserved
    np.testing.assert_array_equal(loaded["loss"], np.ones(2, np.float32))
    json.dumps(rec)                                   # fully serializable


def test_store_traces_legacy_inline_reads(tmp_path):
    """Pre-obs campaigns inlined traces into the JSONL; load_traces
    still reads them."""
    store = CampaignStore("t3", root=str(tmp_path))
    s = Scenario(attack="none", defense="mean")
    rec = {"id": scenario_id(s), "scenario": s.asdict(),
           "result": {"acc": 0.5, "traces": {"loss": [1.0, 2.0]}}}
    with open(store.path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    loaded = store.load_traces(scenario_id(s))
    np.testing.assert_array_equal(loaded["loss"], [1.0, 2.0])


# ------------------------------------------------------- table1 stats


def test_build_rows_multiseed_stats():
    scns = [Scenario(attack="a", defense="d", seed=k) for k in range(3)]
    fake = {scenario_id(s): {"acc": acc, "caught_byz": 4,
                             "evicted_honest": 0}
            for s, acc in zip(scns, (0.4, 0.5, 0.6))}
    rows = table1_attack_grid.build_rows(scns, fake)
    assert len(rows) == 1
    row = rows[0]
    assert row["acc_mean"] == pytest.approx(0.5)
    assert row["acc_std"] == pytest.approx(np.std([0.4, 0.5, 0.6]))
    assert row["acc"] == row["acc_mean"]
    assert row["seeds"] == 3 and row["caught_byz"] == 4
