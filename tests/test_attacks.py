"""Attack semantics: byzantine rows rewritten, honest rows untouched."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks as atk

M = 8
BYZ = jnp.arange(M) < 3


def grads(key=jax.random.PRNGKey(0)):
    return {"w": jax.random.normal(key, (M, 6, 2)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (M, 4))}


def test_none_identity():
    g = grads()
    out, _ = atk.attack_none(g, BYZ, None, jnp.int32(0), None)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(g["w"]))


def test_sign_flip():
    g = grads()
    out, _ = atk.attack_sign_flip(g, BYZ, None, jnp.int32(0), None)
    np.testing.assert_allclose(np.asarray(out["w"][:3]),
                               -np.asarray(g["w"][:3]))
    np.testing.assert_allclose(np.asarray(out["w"][3:]),
                               np.asarray(g["w"][3:]))


def test_scaled_flip():
    g = grads()
    out, _ = atk.make_scaled_flip(0.6)(g, BYZ, None, jnp.int32(0), None)
    np.testing.assert_allclose(np.asarray(out["b"][:3]),
                               -0.6 * np.asarray(g["b"][:3]), rtol=1e-6)


def test_variance_attack_shifts_mean_within_sigma():
    g = grads()
    z = 0.3
    out, _ = atk.make_variance_attack(z)(g, BYZ, None, jnp.int32(0), None)
    gw = np.asarray(g["w"][3:])
    mu, sd = gw.mean(0), gw.std(0)
    np.testing.assert_allclose(np.asarray(out["w"][0]), mu - z * sd,
                               rtol=1e-4, atol=1e-5)
    # collusion: all byzantine rows identical
    np.testing.assert_array_equal(np.asarray(out["w"][0]),
                                  np.asarray(out["w"][2]))


def test_ipm():
    g = grads()
    out, _ = atk.make_ipm(2.0)(g, BYZ, None, jnp.int32(0), None)
    mu = np.asarray(g["w"][3:]).mean(0)
    np.testing.assert_allclose(np.asarray(out["w"][1]), -2.0 * mu,
                               rtol=1e-4, atol=1e-5)


def test_delayed_replays_old_mean():
    g0, g1, g2 = grads(), grads(jax.random.PRNGKey(1)), grads(
        jax.random.PRNGKey(2))
    attack = atk.make_delayed(2)
    state = attack.init(jax.tree.map(lambda x: x[0], g0["w"])
                        if False else {"w": g0["w"][0], "b": g0["b"][0]})
    out0, state = attack(g0, BYZ, state, jnp.int32(0), None)
    out1, state = attack(g1, BYZ, state, jnp.int32(1), None)
    out2, state = attack(g2, BYZ, state, jnp.int32(2), None)
    # step 2 byzantine rows replay the honest mean from step 0
    mu0 = np.asarray(g0["w"][3:]).mean(0)
    np.testing.assert_allclose(np.asarray(out2["w"][0]), mu0,
                               rtol=1e-4, atol=1e-5)


def test_burst_windows():
    attack = atk.make_burst(start=2, length=2, burst_scale=5.0)
    g = grads()
    for t, active in [(0, False), (2, True), (3, True), (4, False)]:
        out, _ = attack(g, BYZ, None, jnp.int32(t), None)
        if active:
            np.testing.assert_allclose(np.asarray(out["w"][0]),
                                       -5.0 * np.asarray(g["w"][0]),
                                       rtol=1e-6)
        else:
            np.testing.assert_array_equal(np.asarray(out["w"][0]),
                                          np.asarray(g["w"][0]))


def test_registry_contains_paper_attacks():
    reg = atk.make_registry()
    for name in ("sign_flip", "variance", "delayed", "label_flip",
                 "safeguard_x0.6", "safeguard_x0.7", "ipm"):
        assert name in reg
    assert reg["label_flip"].data_attack
