"""Attack semantics: byzantine rows rewritten, honest rows untouched."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks as atk

M = 8
BYZ = jnp.arange(M) < 3


def grads(key=jax.random.PRNGKey(0)):
    return {"w": jax.random.normal(key, (M, 6, 2)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (M, 4))}


def test_none_identity():
    g = grads()
    out, _ = atk.attack_none(g, BYZ, None, jnp.int32(0), None)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(g["w"]))


def test_sign_flip():
    g = grads()
    out, _ = atk.attack_sign_flip(g, BYZ, None, jnp.int32(0), None)
    np.testing.assert_allclose(np.asarray(out["w"][:3]),
                               -np.asarray(g["w"][:3]))
    np.testing.assert_allclose(np.asarray(out["w"][3:]),
                               np.asarray(g["w"][3:]))


def test_scaled_flip():
    g = grads()
    out, _ = atk.make_scaled_flip(0.6)(g, BYZ, None, jnp.int32(0), None)
    np.testing.assert_allclose(np.asarray(out["b"][:3]),
                               -0.6 * np.asarray(g["b"][:3]), rtol=1e-6)


def test_variance_attack_shifts_mean_within_sigma():
    g = grads()
    z = 0.3
    out, _ = atk.make_variance_attack(z)(g, BYZ, None, jnp.int32(0), None)
    gw = np.asarray(g["w"][3:])
    mu, sd = gw.mean(0), gw.std(0)
    np.testing.assert_allclose(np.asarray(out["w"][0]), mu - z * sd,
                               rtol=1e-4, atol=1e-5)
    # collusion: all byzantine rows identical
    np.testing.assert_array_equal(np.asarray(out["w"][0]),
                                  np.asarray(out["w"][2]))


def test_ipm():
    g = grads()
    out, _ = atk.make_ipm(2.0)(g, BYZ, None, jnp.int32(0), None)
    mu = np.asarray(g["w"][3:]).mean(0)
    np.testing.assert_allclose(np.asarray(out["w"][1]), -2.0 * mu,
                               rtol=1e-4, atol=1e-5)


def test_delayed_replays_old_mean():
    g0, g1, g2 = grads(), grads(jax.random.PRNGKey(1)), grads(
        jax.random.PRNGKey(2))
    attack = atk.make_delayed(2)
    state = attack.init(jax.tree.map(lambda x: x[0], g0["w"])
                        if False else {"w": g0["w"][0], "b": g0["b"][0]})
    out0, state = attack(g0, BYZ, state, jnp.int32(0), None)
    out1, state = attack(g1, BYZ, state, jnp.int32(1), None)
    out2, state = attack(g2, BYZ, state, jnp.int32(2), None)
    # step 2 byzantine rows replay the honest mean from step 0
    mu0 = np.asarray(g0["w"][3:]).mean(0)
    np.testing.assert_allclose(np.asarray(out2["w"][0]), mu0,
                               rtol=1e-4, atol=1e-5)


def test_burst_windows():
    attack = atk.make_burst(start=2, length=2, burst_scale=5.0)
    g = grads()
    for t, active in [(0, False), (2, True), (3, True), (4, False)]:
        out, _ = attack(g, BYZ, None, jnp.int32(t), None)
        if active:
            np.testing.assert_allclose(np.asarray(out["w"][0]),
                                       -5.0 * np.asarray(g["w"][0]),
                                       rtol=1e-6)
        else:
            np.testing.assert_array_equal(np.asarray(out["w"][0]),
                                          np.asarray(g["w"][0]))


def test_registry_contains_paper_attacks():
    reg = atk.make_registry()
    for name in ("sign_flip", "variance", "delayed", "label_flip",
                 "safeguard_x0.6", "safeguard_x0.7", "ipm"):
        assert name in reg
    assert reg["label_flip"].data_attack


def test_registry_contains_adaptive_attacks():
    reg = atk.make_registry()
    for name in ("adaptive_flip", "adaptive_variance", "oscillating",
                 "median_capture"):
        assert name in reg
        assert reg[name].adaptive and reg[name].init is not None


def test_registry_burst_window_derived_from_steps():
    """burst_start=None derives the window from the trial length so the
    burst always fires; an explicit unfireable window fails loudly."""
    reg = atk.make_registry(steps=90)
    g = grads()
    # derived start = 90 // 3 = 30: active at t=30, honest at t=0
    out, _ = reg["burst"].act(g, BYZ, None, jnp.int32(30), None)
    np.testing.assert_allclose(np.asarray(out["w"][0]),
                               -5.0 * np.asarray(g["w"][0]), rtol=1e-6)
    out, _ = reg["burst"].act(g, BYZ, None, jnp.int32(0), None)
    np.testing.assert_array_equal(np.asarray(out["w"][0]),
                                  np.asarray(g["w"][0]))
    with pytest.raises(ValueError, match="never fire"):
        atk.make_registry(burst_start=200, steps=100)


# ------------------------------------------------- feedback-coupled attacks


def fb(m=M, **kw):
    out = atk.null_feedback(m)
    out.update({k: jnp.asarray(v) for k, v in kw.items()})
    return out


def test_null_feedback_shapes():
    f = atk.null_feedback(M)
    assert f["good"].shape == (M,) and bool(f["good"].all())
    assert f["dist_to_med"].shape == (M,)
    assert float(f["threshold"]) == pytest.approx(atk.OPEN_LOOP_THRESHOLD,
                                                  rel=1e-6)


def test_adaptive_flip_ramps_against_no_defense():
    attack = atk.make_adaptive_flip(init_scale=0.2, up=1.08)
    state = attack.init(None)
    for _ in range(100):
        state = attack.observe(state, fb(), BYZ)
    # unbounded headroom: the controller ramps to its aggression cap
    assert float(state["aggr"]) == pytest.approx(4.0)
    g = grads()
    out, _ = attack.act(g, BYZ, state, jnp.int32(0), None)
    np.testing.assert_allclose(np.asarray(out["w"][0]),
                               -3.0 * np.asarray(g["w"][0]), rtol=1e-5)


def test_adaptive_flip_eases_near_threshold_and_backs_off_when_caught():
    attack = atk.make_adaptive_flip(init_scale=0.5, down=0.5, target=0.8)
    state = attack.init(None)
    # colluder at 95% of the live threshold -> ease off (ratio < 1)
    d = jnp.zeros((M,)).at[0].set(0.95)
    near = fb(dist_to_med=d, threshold=1.0)
    s1 = attack.observe(state, near, BYZ)
    assert float(s1["aggr"]) < float(state["aggr"])
    # a colluder newly caught -> hard back-off by `down`
    caught = fb(good=jnp.arange(M) != 0)
    s2 = attack.observe(state, caught, BYZ)
    assert float(s2["aggr"]) == pytest.approx(float(state["aggr"]) * 0.5)
    # the same eviction observed again is not "new": no further back-off
    s3 = attack.observe(s2, caught, BYZ)
    assert float(s3["aggr"]) >= float(s2["aggr"])


def test_adaptive_flip_tracks_second_guard():
    """The binding guard governs: headroom on B but a colluder at 95% of
    the A threshold must still ease off."""
    attack = atk.make_adaptive_flip(init_scale=0.5)
    state = attack.init(None)
    d = jnp.zeros((M,)).at[1].set(1.9)
    s1 = attack.observe(state, fb(dist_to_med_A=d, threshold_A=2.0), BYZ)
    assert float(s1["aggr"]) < float(state["aggr"])


def test_adaptive_variance_shrinks_z_on_new_eviction():
    attack = atk.make_adaptive_variance(z_init=0.4, up=1.05, down=0.5)
    state = attack.init(None)
    grown = attack.observe(state, fb(), BYZ)
    assert float(grown["z"]) == pytest.approx(0.4 * 1.05)
    shrunk = attack.observe(state, fb(good=jnp.arange(M) != 2), BYZ)
    assert float(shrunk["z"]) == pytest.approx(0.4 * 0.5)
    # act uses the live z and keeps the collusive mu - z*sigma form
    g = grads()
    out, _ = attack.act(g, BYZ, state, jnp.int32(0), None)
    gw = np.asarray(g["w"][3:])
    np.testing.assert_allclose(np.asarray(out["w"][0]),
                               gw.mean(0) - 0.4 * gw.std(0),
                               rtol=1e-3, atol=1e-4)


def test_oscillating_hysteresis_and_honest_freeze():
    attack = atk.make_oscillating(init_scale=1.5, up=1.1, high=0.8,
                                  low=0.4)
    state = attack.init(None)
    g = grads()
    # attacking phase: byz rows flipped
    out, _ = attack.act(g, BYZ, state, jnp.int32(0), None)
    np.testing.assert_allclose(np.asarray(out["w"][0]),
                               -1.5 * np.asarray(g["w"][0]), rtol=1e-5)
    # crossing the high-water mark freezes the attack (honest behavior)
    d = jnp.zeros((M,)).at[0].set(0.9)
    state = attack.observe(state, fb(dist_to_med=d, threshold=1.0), BYZ)
    assert float(state["attacking"]) == 0.0
    assert float(state["scale"]) == pytest.approx(1.5)   # no ramp frozen
    out, _ = attack.act(g, BYZ, state, jnp.int32(1), None)
    np.testing.assert_array_equal(np.asarray(out["w"][0]),
                                  np.asarray(g["w"][0]))
    # in the hysteresis band the phase holds; below low it resumes and
    # ramps while the headroom lasts
    d = jnp.zeros((M,)).at[0].set(0.6)
    state = attack.observe(state, fb(dist_to_med=d, threshold=1.0), BYZ)
    assert float(state["attacking"]) == 0.0
    state = attack.observe(state, fb(), BYZ)
    assert float(state["attacking"]) == 1.0
    assert float(state["scale"]) == pytest.approx(1.5 * 1.1)


def test_median_capture_greedy_while_holding_median():
    attack = atk.make_median_capture(eps_init=0.1, up=1.1, down=0.5)
    state = attack.init(None)
    # a byzantine worker holds the median -> ramp eps greedily
    held = attack.observe(state, fb(med=jnp.int32(0)), BYZ)
    assert float(held["eps"]) == pytest.approx(0.1 * 1.1)
    # median lost (honest worker) -> retreat toward honest mimicry
    lost = attack.observe(state, fb(med=jnp.int32(7)), BYZ)
    assert float(lost["eps"]) == pytest.approx(0.1 * 0.5)
    # all colluders report the identical (1 - eps) * honest mean
    g = grads()
    out, _ = attack.act(g, BYZ, state, jnp.int32(0), None)
    mu = np.asarray(g["w"][3:]).mean(0)
    np.testing.assert_allclose(np.asarray(out["w"][0]), 0.9 * mu,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(out["w"][0]),
                                  np.asarray(out["w"][2]))
