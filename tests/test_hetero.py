"""Worker-heterogeneity subsystem (DESIGN.md §13): the non-IID data
models, the zeta dissimilarity trace layer, bucketing as a meta-defense
in the engine, construction-time grid validation, and the subsystem's
acceptance separation at strong label skew.

The statistical properties of the Dirichlet partitioner also have
hypothesis twins in ``tests/test_property.py``; the concrete versions
here keep the invariants covered when hypothesis is unavailable.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.campaign import engine
from repro.campaign.scenario import (HETERO_DEFENSES, Scenario,
                                     expand_grid, scenario_id)
from repro.data import hetero as H
from repro.data import tasks
from repro.data.pipeline import worker_split

TASK = tasks.make_teacher_task()


# ------------------------------------------------------------ data models


def test_dirichlet_exact_shapes_and_support():
    key = jax.random.fold_in(jax.random.PRNGKey(0 ^ 0xDA7A), 3)
    w = H.worker_mixtures(H.mixture_key(0), 0.05, 10, 10)
    assert w.shape == (10, 10)
    np.testing.assert_allclose(np.asarray(w.sum(axis=1)), 1.0, atol=1e-5)
    out = H.hetero_worker_batch(TASK, key, 100, 10, mode="dirichlet",
                                weights=w, alpha=0.05)
    assert out["x"].shape == (10, 10, TASK.d_in)
    assert out["y"].shape == (10, 10) and out["y"].dtype == jnp.int32


def test_dirichlet_strong_skew_concentrates_labels():
    """At alpha = 0.05 a worker's shard is dominated by very few classes
    — the non-IID regime the subsystem exists to express."""
    key = jax.random.fold_in(jax.random.PRNGKey(0 ^ 0xDA7A), 0)
    w = H.worker_mixtures(H.mixture_key(0), 0.05, 10, 10)
    out = H.hetero_worker_batch(TASK, key, 400, 10, mode="dirichlet",
                                weights=w, alpha=0.05)
    y = np.asarray(out["y"])
    top_frac = [np.bincount(y[i], minlength=10).max() / y.shape[1]
                for i in range(10)]
    assert np.mean(top_frac) > 0.6
    # ... while the IID split stays spread out
    iid = worker_split(tasks.teacher_batch(TASK, key, 400), 10)
    y0 = np.asarray(iid["y"])
    iid_frac = [np.bincount(y0[i], minlength=10).max() / y0.shape[1]
                for i in range(10)]
    assert np.mean(top_frac) > np.mean(iid_frac) + 0.2


def test_dirichlet_inactive_alpha_is_iid_bitexact():
    """alpha -> inf (the Dirichlet limit) and alpha <= 0 (the off
    sentinel) both reproduce the contiguous IID split bit-for-bit."""
    key = jax.random.fold_in(jax.random.PRNGKey(7 ^ 0xDA7A), 11)
    iid = worker_split(tasks.teacher_batch(TASK, key, 100), 10)
    for alpha in (float("inf"), 0.0, -1.0):
        w = H.worker_mixtures(H.mixture_key(7), alpha, 10, 10)
        got = H.hetero_worker_batch(TASK, key, 100, 10, mode="dirichlet",
                                    weights=w, alpha=alpha)
        assert np.array_equal(np.asarray(got["x"]), np.asarray(iid["x"]))
        assert np.array_equal(np.asarray(got["y"]), np.asarray(iid["y"]))


def test_one_hot_mixture_gives_pure_class_shards():
    labels = jnp.concatenate([jnp.arange(6),
                              jax.random.randint(jax.random.PRNGKey(2),
                                                 (18,), 0, 6)])
    idx = H.dirichlet_indices(jax.random.PRNGKey(2), labels,
                              jnp.eye(6, dtype=jnp.float32), 6, 4)
    picked = np.asarray(labels)[np.asarray(idx)]
    np.testing.assert_array_equal(picked,
                                  np.arange(6)[:, None] * np.ones((1, 4),
                                                                  int))


def test_shift_model_rotates_labels_not_inputs():
    key = jax.random.fold_in(jax.random.PRNGKey(0 ^ 0xDA7A), 5)
    iid = worker_split(tasks.teacher_batch(TASK, key, 100), 10)
    out = H.hetero_worker_batch(TASK, key, 100, 10, mode="shift",
                                shift=1.5)
    # concept shift: P(y | x) changes, the inputs do not
    assert np.array_equal(np.asarray(out["x"]), np.asarray(iid["x"]))
    frac = float((out["y"] != iid["y"]).mean())
    assert 0.1 < frac < 0.9
    # shift = 0 is bit-for-bit IID
    off = H.hetero_worker_batch(TASK, key, 100, 10, mode="shift",
                                shift=0.0)
    assert np.array_equal(np.asarray(off["y"]), np.asarray(iid["y"]))
    # angles are spread symmetrically over [-shift, +shift]
    ang = np.asarray(H.shift_angles(1.5, 10))
    assert ang[0] == pytest.approx(-1.5) and ang[-1] == pytest.approx(1.5)


def test_rotate_pairs_is_norm_preserving_and_invertible():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 6))
    r = H.rotate_pairs(x, jnp.asarray(0.7))
    np.testing.assert_allclose(np.asarray((r * r).sum(-1)),
                               np.asarray((x * x).sum(-1)), rtol=1e-5)
    back = H.rotate_pairs(r, jnp.asarray(-0.7))
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-5)
    # odd trailing coordinate passes through
    x5 = jax.random.normal(jax.random.PRNGKey(1), (3, 5))
    r5 = H.rotate_pairs(x5, jnp.asarray(1.1))
    np.testing.assert_array_equal(np.asarray(r5[..., 4]),
                                  np.asarray(x5[..., 4]))


def test_hetero_batches_iterator_matches_engine_key_schedule():
    """The legacy-Trainer iterator and a hand-built engine-style batch_fn
    produce identical streams (the bit-identity substrate)."""
    it = H.hetero_batches(TASK, 60, mode="dirichlet", alpha=0.2, seed=3,
                          m=6)
    w = H.worker_mixtures(H.mixture_key(3), 0.2, 6, TASK.n_classes)
    for t in range(3):
        a = next(it)
        key = jax.random.fold_in(jax.random.PRNGKey(3 ^ 0xDA7A), t)
        b = H.hetero_worker_batch(TASK, key, 60, 6, mode="dirichlet",
                                  weights=w, alpha=0.2)
        assert np.array_equal(np.asarray(a["x"]), np.asarray(b["x"]))
        assert np.array_equal(np.asarray(a["y"]), np.asarray(b["y"]))


def test_unknown_hetero_mode_fails_loudly():
    with pytest.raises(ValueError, match="unknown hetero model"):
        H.hetero_worker_batch(TASK, jax.random.PRNGKey(0), 10, 2,
                              mode="nope")


# ------------------------------------------------- grid-time validation


def test_batch_divisibility_validated_at_scenario_construction():
    """Satellite: the bad axis fails at grid construction with the
    scenario named — not as a reshape error from inside a traced scan."""
    with pytest.raises(ValueError, match=r"variance/mean.*batch=101"):
        Scenario(attack="variance", defense="mean", batch=101)
    with pytest.raises(ValueError, match="not divisible"):
        expand_grid(attack=["variance"], defense=["mean"], batch=[90, 101])
    # the boundary cases still construct
    Scenario(attack="variance", defense="mean", batch=100, m=10)
    Scenario(attack="variance", defense="mean", batch=10, m=10)


def test_bucketing_shape_validated_at_scenario_construction():
    with pytest.raises(ValueError, match="bucket_s"):
        Scenario(attack="none", defense="bucketing_krum", m=10, bucket_s=3)
    with pytest.raises(ValueError, match="unknown hetero model"):
        Scenario(attack="none", defense="mean", hetero="zipf")
    Scenario(attack="none", defense="bucketing_krum", m=10, bucket_s=2)


def test_scenario_id_unorphaned_by_hetero_and_bucket_fields():
    """Satellite: the new defaulted knobs are excluded from the content
    hash, so every previously stored campaign cell keeps its key; a
    non-default hetero knob re-keys exactly the cells it changes."""
    import hashlib
    import json
    s = Scenario(attack="a", defense="d", steps=99)
    expect = hashlib.sha256(json.dumps(
        {"attack": "a", "defense": "d", "steps": 99},
        sort_keys=True).encode()).hexdigest()[:16]
    assert scenario_id(s) == expect               # pre-PR key, unchanged
    ids = {scenario_id(x) for x in (
        s,
        dataclasses.replace(s, hetero="dirichlet", hetero_alpha=0.1),
        dataclasses.replace(s, hetero="dirichlet", hetero_alpha=0.05),
        dataclasses.replace(s, hetero="shift", hetero_shift=1.0),
        dataclasses.replace(s, threshold_scale=2.0),
    )}
    assert len(ids) == 5
    # bucket_s at its default stays out of the hash for bucketing cells
    b = Scenario(attack="a", defense="bucketing_krum")
    assert scenario_id(b) == scenario_id(
        dataclasses.replace(b, bucket_s=2))
    assert scenario_id(b) != scenario_id(
        dataclasses.replace(b, bucket_s=1))


# ----------------------------------------------------- engine integration


STEPS = 30


def test_hetero_knobs_are_vmap_axes():
    """hetero_alpha feeds only fixed-shape sampling arithmetic, so all
    alpha variants (including the inf IID sentinel) run as lanes of one
    program — and the traced knob changes the outcome."""
    scns = [Scenario(attack="variance", defense="safeguard_double",
                     steps=STEPS, hetero="dirichlet", hetero_alpha=a)
            for a in (0.05, 10.0, float("inf"))]
    assert len(engine.group_scenarios(scns)) == 1
    res = engine.run_scenarios(scns)
    lo, hi, inf = (res[scenario_id(s)] for s in scns)
    assert not np.array_equal(lo["traces"]["loss"], hi["traces"]["loss"])
    # the inf lane is bit-identical to the separately traced IID program
    iid = Scenario(attack="variance", defense="safeguard_double",
                   steps=STEPS)
    r_iid = engine.run_scenarios([iid])[scenario_id(iid)]
    assert inf["acc"] == r_iid["acc"]
    assert np.array_equal(inf["traces"]["loss"], r_iid["traces"]["loss"])

    scns = [Scenario(attack="none", defense="mean", steps=STEPS,
                     hetero="shift", hetero_shift=sh)
            for sh in (0.3, 1.5)]
    assert len(engine.group_scenarios(scns)) == 1
    res = engine.run_scenarios(scns)
    a, b = (res[scenario_id(s)] for s in scns)
    assert not np.array_equal(a["traces"]["loss"], b["traces"]["loss"])


def test_hetero_vmap_matches_unbatched_bitexact():
    """Acceptance: vmapped-vs-unbatched equivalence over a hetero_alpha
    axis (gamma/Gumbel sampling batches bit-stably)."""
    scns = [Scenario(attack="variance", defense="safeguard_double",
                     steps=STEPS, hetero="dirichlet", hetero_alpha=a,
                     seed=k)
            for a in (0.05, 1.0) for k in (0, 1)]
    assert len(engine.group_scenarios(scns)) == 1
    batched = engine.run_scenarios(scns, batched=True)
    unbatched = engine.run_scenarios(scns, batched=False)
    for s in scns:
        b, u = batched[scenario_id(s)], unbatched[scenario_id(s)]
        for key in b["traces"]:
            assert np.array_equal(b["traces"][key], u["traces"][key]), \
                (s.hetero_alpha, s.seed, key)
        assert np.array_equal(b["final_good"], u["final_good"])
        assert b["acc"] == u["acc"]


def test_zeta_traces_measure_heterogeneity():
    """The dissimilarity trace layer: zeta_sq is recorded every step and
    grows with label skew."""
    scns = [Scenario(attack="none", defense="mean", steps=STEPS,
                     hetero="dirichlet", hetero_alpha=a)
            for a in (0.05, float("inf"))]
    res = engine.run_scenarios(scns)
    skew, iid = (res[scenario_id(s)] for s in scns)
    for rec in (skew, iid):
        for key in ("zeta_sq", "zeta_good_sq"):
            assert rec["traces"][key].shape == (STEPS,)
            assert (rec["traces"][key] > 0).all()
    assert skew["zeta_sq_mean"] > 1.3 * iid["zeta_sq_mean"]
    # with no filtering defense the defense-view zeta includes the
    # (honest-acting) byzantine rows: equal masks -> equal estimates on
    # the all-good steps
    assert skew["traces"]["zeta_good_sq"].shape == (STEPS,)


def test_bucketing_defenses_in_engine_vmap_bitexact():
    """The meta-defense's permutation stream (scan-threaded rng) and the
    inner state batch correctly over seeds."""
    for defense in ("bucketing_krum", "bucketing_cclip"):
        scns = [Scenario(attack="variance", defense=defense, steps=STEPS,
                         seed=k) for k in (0, 1)]
        assert len(engine.group_scenarios(scns)) == 1
        batched = engine.run_scenarios(scns, batched=True)
        unbatched = engine.run_scenarios(scns, batched=False)
        for s in scns:
            b, u = batched[scenario_id(s)], unbatched[scenario_id(s)]
            for key in b["traces"]:
                assert np.array_equal(b["traces"][key],
                                      u["traces"][key]), (defense, key)
            assert b["acc"] == u["acc"], defense


def test_bucket_s_is_program_structure():
    """Different bucket counts change the traced shapes, so bucket_s
    partitions batch groups (like static n_byz), and the engine passes
    it through to the registry; a bucket count too small for the inner
    rule fails at construction, not mid-trace."""
    scns = [Scenario(attack="none", defense="bucketing_krum", steps=8,
                     bucket_s=s) for s in (1, 2)]
    assert len(engine.group_scenarios(scns)) == 2
    res = engine.run_scenarios(scns)
    a, b = (res[scenario_id(s)] for s in scns)
    assert not np.array_equal(a["traces"]["loss"], b["traces"]["loss"])
    with pytest.raises(ValueError, match="buckets"):
        Scenario(attack="none", defense="bucketing_krum", bucket_s=5)


# ------------------------------------------------ acceptance: separation


def test_separation_at_strong_skew():
    """Acceptance (ISSUE 5): at strong skew (alpha = 0.1, no attack)
    krum and trimmed_mean lose measurable accuracy vs mean, while
    bucketing(krum) and centered_clip recover it, and SafeguardSGD (at
    the zeta-relaxed eviction scale) evicts no honest worker; traces
    record measured zeta per step."""
    seeds = (0, 1)
    alpha = 0.1

    def cells(defense, **kw):
        return [Scenario(attack="none", defense=defense, steps=150,
                         seed=k, hetero="dirichlet", hetero_alpha=alpha,
                         **kw) for k in seeds]

    grid = {d: cells(d) for d in ("mean", "krum", "trimmed_mean",
                                  "centered_clip", "bucketing_krum")}
    grid["safeguard_double"] = cells("safeguard_double",
                                     threshold_scale=2.0)
    res = engine.run_scenarios([s for ss in grid.values() for s in ss])

    def acc(d):
        return float(np.mean([res[scenario_id(s)]["acc"]
                              for s in grid[d]]))

    a_mean, a_krum, a_trim = acc("mean"), acc("krum"), acc("trimmed_mean")
    a_cc, a_bucket = acc("centered_clip"), acc("bucketing_krum")
    a_sg = acc("safeguard_double")
    # selection-style rules lock onto single skewed shards and lose
    assert a_krum < a_mean - 0.10, (a_krum, a_mean)
    assert a_trim < a_mean - 0.04, (a_trim, a_mean)
    # bucketing repairs krum; bounded-influence clipping tracks mean
    assert a_bucket > a_krum + 0.08, (a_bucket, a_krum)
    assert a_cc > a_mean - 0.06, (a_cc, a_mean)
    assert a_sg > a_mean - 0.08, (a_sg, a_mean)
    # the zeta-relaxed safeguard evicts nobody (everyone is honest here)
    for s in grid["safeguard_double"]:
        assert res[scenario_id(s)]["caught_byz"] == 0, s.seed
        assert res[scenario_id(s)]["evicted_honest"] == 0, s.seed
    # measured zeta is traced for every cell of the campaign
    for ss in grid.values():
        for s in ss:
            tr = res[scenario_id(s)]["traces"]["zeta_sq"]
            assert tr.shape == (150,) and (tr > 0).all()
