"""Hypothesis property tests on the system's invariants.

Skips cleanly (instead of crashing collection) when ``hypothesis`` is not
installed — it is an optional dev dependency, not a runtime one."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
hnp = pytest.importorskip("hypothesis.extra.numpy")
st = pytest.importorskip("hypothesis.strategies")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import SafeguardConfig, init_state, safeguard_step
from repro.core import aggregators as agg
from repro.core import attacks as atk
from repro.core import defenses as dfn
from repro.core import tree_utils as tu
from repro.core import sketch as sk

SET = dict(deadline=None, max_examples=25,
           suppress_health_check=[hypothesis.HealthCheck.too_slow])

finite = st.floats(-10, 10, allow_nan=False, width=32)


def stacks(m_min=4, m_max=12, d_max=8):
    return hnp.arrays(np.float32,
                      st.tuples(st.integers(m_min, m_max),
                                st.integers(1, d_max),
                                st.integers(1, d_max)),
                      elements=finite)


@given(stacks())
@settings(**SET)
def test_gram_matches_numpy(arr):
    g = {"x": jnp.asarray(arr)}
    gram = np.asarray(tu.tree_gram(g))
    flat = arr.reshape(arr.shape[0], -1).astype(np.float64)
    np.testing.assert_allclose(gram, flat @ flat.T, rtol=1e-3, atol=1e-3)


@given(stacks())
@settings(**SET)
def test_sqdist_nonneg_symmetric_zero_diag(arr):
    d = np.asarray(tu.tree_pairwise_sqdist({"x": jnp.asarray(arr)}))
    assert (d >= 0).all()
    np.testing.assert_allclose(d, d.T, atol=1e-3)
    np.testing.assert_allclose(np.diagonal(d), 0.0, atol=1e-3)


@given(stacks(), st.integers(0, 1000))
@settings(**SET)
def test_coord_median_bounded_and_permutation_invariant(arr, seed):
    g = {"x": jnp.asarray(arr)}
    med = np.asarray(agg.coordinate_median(g)["x"])
    assert (med >= arr.min(axis=0) - 1e-6).all()
    assert (med <= arr.max(axis=0) + 1e-6).all()
    perm = np.random.RandomState(seed).permutation(arr.shape[0])
    med2 = np.asarray(agg.coordinate_median({"x": jnp.asarray(arr[perm])})["x"])
    np.testing.assert_allclose(med, med2, atol=1e-6)


@given(stacks(m_min=6))
@settings(**SET)
def test_trimmed_mean_bounded(arr):
    out = np.asarray(agg.trimmed_mean({"x": jnp.asarray(arr)}, trim=1)["x"])
    s = np.sort(arr, axis=0)
    assert (out >= s[1] - 1e-5).all() and (out <= s[-2] + 1e-5).all()


@given(stacks(m_min=6), st.integers(1, 2))
@settings(**SET)
def test_krum_returns_a_worker(arr, b):
    g = {"x": jnp.asarray(arr)}
    out = np.asarray(agg.krum(g, n_byz=b)["x"])
    assert any(np.allclose(out, arr[i], atol=1e-6)
               for i in range(arr.shape[0]))


@given(st.integers(4, 12), st.integers(0, 5), st.integers(0, 2 ** 31 - 1))
@settings(**SET)
def test_honest_execution_never_evicts(m, steps_extra, seed):
    """Concentration guarantee (Lemma 3.2) at test scale: with threshold
    floor above the noise level, no honest worker is ever evicted."""
    key = jax.random.PRNGKey(seed)
    cfg = SafeguardConfig(m=m, T0=8, T1=24, threshold_floor=1.0)
    params = {"w": jnp.zeros((6, 3))}
    stt = init_state(cfg, params)
    for t in range(10 + steps_extra):
        key, k = jax.random.split(key)
        g = {"w": 1.0 + 0.05 * jax.random.normal(k, (m, 6, 3))}
        stt, _, _ = safeguard_step(stt, g, cfg)
    assert bool(stt.good.all())


@given(st.integers(0, 2 ** 31 - 1))
@settings(**SET)
def test_safeguard_permutation_equivariance(seed):
    """Relabeling workers permutes the good-mask identically."""
    m = 8
    key = jax.random.PRNGKey(seed)
    perm = jax.random.permutation(jax.random.fold_in(key, 1), m)
    cfg = SafeguardConfig(m=m, T0=8, T1=16, threshold_floor=0.3)
    byz = jnp.arange(m) < 3

    def run(order):
        stt = init_state(cfg, {"w": jnp.zeros((5,))})
        kk = key
        for t in range(20):
            kk, k = jax.random.split(kk)
            g = {"w": 1.0 + 0.05 * jax.random.normal(k, (m, 5))}
            g, _ = atk.attack_sign_flip(g, byz, None, jnp.int32(t), k)
            g = {"w": g["w"][order]}
            stt, _, _ = safeguard_step(stt, g, cfg)
        return stt.good

    base = run(jnp.arange(m))
    permuted = run(perm)
    np.testing.assert_array_equal(np.asarray(base)[np.asarray(perm)],
                                  np.asarray(permuted))


@given(st.floats(1e2, 1e5), st.integers(2, 10),
       st.integers(0, 2 ** 31 - 1))
@settings(**SET)
def test_near_duplicate_rows_never_nan_any_sqdist_path(mag, m, seed):
    """NaN regression (ISSUE 3): near-duplicate large-magnitude rows make
    ``diag_i + diag_j - 2 G_ij`` cancel below zero in f32; every sqdist
    producer must clamp at 0 so ``sqrt`` never sees a negative — a NaN
    distance compares False against the threshold and silently evicts
    honest workers."""
    d = 256
    key = jax.random.PRNGKey(seed)
    base = mag * jax.random.normal(key, (1, d))
    rows = base + 1e-6 * mag * jax.random.normal(
        jax.random.fold_in(key, 1), (m, d))
    from repro.kernels.safeguard_filter import (fused_accumulate_sqdist,
                                                pairwise_sqdist)
    from repro.kernels.safeguard_filter import ref as sf_ref
    outs = {
        "pallas": pairwise_sqdist(rows),
        "ref": sf_ref.pairwise_sqdist(rows),
        "tree": tu.tree_pairwise_sqdist({"x": rows}),
        "fused": fused_accumulate_sqdist(
            jnp.zeros_like(rows), rows, 0, 1.0)[1],
        "sketch": sk.sketch_pairwise_sqdist(
            sk.sketch_tree({"x": rows}, k=128, reps=2)),
    }
    for name, sq in outs.items():
        sq = np.asarray(sq)
        assert np.isfinite(sq).all(), name
        assert (sq >= 0).all(), name
        assert np.isfinite(np.sqrt(sq)).all(), name


@given(hnp.arrays(np.float32, st.tuples(st.integers(2, 6),
                                        st.integers(64, 256)),
                  elements=finite))
@settings(**SET)
def test_sketch_preserves_distance_ordering(arr):
    """JL property (statistical): sketched distances approximate exact
    distances within generous relative error for well-separated pairs."""
    g = {"x": jnp.asarray(arr)}
    exact = np.asarray(tu.tree_pairwise_sqdist(g))
    sks = sk.sketch_tree(g, k=1024, reps=4, seed=0)
    approx = np.asarray(sk.sketch_pairwise_sqdist(sks))
    m = arr.shape[0]
    for i in range(m):
        for j in range(m):
            if exact[i, j] > 1e-3:
                assert abs(approx[i, j] - exact[i, j]) < 0.5 * exact[i, j] \
                    + 1e-2


@given(st.integers(1, 40), st.integers(2, 30))
@settings(**SET)
def test_ring_from_full_property(L, S):
    from repro.models import layers
    full = jnp.arange(L, dtype=jnp.float32)[None, :, None]
    ring = np.asarray(layers.ring_from_full(full, S))[0, :, 0]
    for p in range(max(0, L - S), L):
        assert ring[p % S] == p


# ------------------------------------------------- Defense protocol zoo

# Backends exercised for the safeguard-family defenses: the Pallas Gram
# kernel (interpret mode on CPU) and the sharded-mesh XLA dot path.
_SG_BACKENDS = ("pallas", "xla")


def _registry_for(m, n_byz, backend="pallas"):
    reg = dfn.make_registry(m, n_byz, T0=4, T1=8, threshold_floor=0.5)
    for name in ("safeguard_single", "safeguard_double"):
        cfg = SafeguardConfig(m=m, T0=4, T1=8, threshold_floor=0.5,
                              mode=name.split("_")[1], backend=backend)
        reg[name] = dfn.make_safeguard_defense(cfg, name)
    return reg


def _normal_stack(m, d, seed):
    """Tie-free random stack (continuous normals: permutation argmin/argsort
    tie-breaks are measure-zero, unlike hypothesis's raw float arrays)."""
    return jax.random.normal(jax.random.PRNGKey(seed), (m, d))


def _clustered_stack(m, d, seed, outliers=2):
    """Tight honest cluster + far outlier rows: every *eviction margin* is
    wide.  The empirical filter's median is 'any worker satisfying ...'
    (paper Alg 1) — when two workers share the k-th order-statistic
    distance EXACTLY (the same symmetric edge), argmin tie-breaks are
    index-order-dependent by spec, so equivariance of the good mask is
    only meaningful when the tie cannot flip a decision."""
    base = 1.0 + 0.05 * jax.random.normal(jax.random.PRNGKey(seed), (m, d))
    return base.at[:outliers].add(5.0)


def _run_steps(d, mat, perm=None, steps=2):
    """Run ``steps`` aggregations (state warms up), permuting the worker
    rows of every input by ``perm``."""
    state = (d.init_state({"w": jnp.zeros((mat.shape[1],))})
             if d.init_state else None)
    ctx = {}
    for t in range(steps):
        g = mat + 0.1 * t
        if perm is not None:
            g = g[perm]
        if d.needs_held_batch:
            scores = -jnp.sum(g.astype(jnp.float32) ** 2, axis=1)
            ctx = {"scores": scores}
        agg_out, state, info = d.aggregate(state, {"w": g}, ctx)
    return agg_out, info


@given(st.integers(5, 10), st.integers(0, 2 ** 31 - 1),
       st.integers(0, 2 ** 31 - 1))
@settings(**{**SET, "max_examples": 10})   # interpreted Pallas dominates
def test_every_registry_defense_permutation_equivariant(m, seed, pseed):
    """Satellite: relabeling workers permutes the good mask and leaves the
    aggregate unchanged, for EVERY defense of the protocol registry (the
    safeguard family across both distance backends)."""
    perm = np.random.RandomState(pseed).permutation(m)
    mat = _clustered_stack(m, 6, seed)
    # n_byz=1 keeps Krum's neighborhood k = m - b - 2 >= 2: at k = 1
    # mutual nearest neighbors tie EXACTLY (the same symmetric distance),
    # and argmin tie-breaks are index-order-dependent by construction
    regs = [_registry_for(m, 1, b) for b in _SG_BACKENDS]
    seen = set()
    for reg in regs:
        for name, d in reg.items():
            if name in seen and not name.startswith("safeguard"):
                continue
            seen.add(name)
            agg_base, info_base = _run_steps(d, mat)
            agg_perm, info_perm = _run_steps(d, mat, perm=perm)
            np.testing.assert_allclose(
                np.asarray(agg_base["w"]), np.asarray(agg_perm["w"]),
                rtol=2e-4, atol=2e-5, err_msg=name)
            np.testing.assert_array_equal(
                np.asarray(info_base["good"])[perm],
                np.asarray(info_perm["good"]), err_msg=name)


# Defenses with a bounded-influence guarantee against a single Byzantine
# row (mean is excluded by definition; weiszfeld's smoothed iterate is
# bounded but we assert the exact-median forms only).
_ROBUST = ("coord_median", "trimmed_mean", "geo_median", "krum", "zeno",
           "safeguard_single", "safeguard_double", "centered_clip",
           "norm_filter", "dnc", "safeguard_cclip")


@given(st.integers(6, 10), st.integers(0, 2 ** 31 - 1),
       st.floats(1e2, 1e6))
@settings(**{**SET, "max_examples": 15})
def test_robust_defenses_bound_single_byzantine_row(m, seed, mag):
    """Satellite: one colluder at magnitude ``mag`` moves a robust
    defense's aggregate by O(honest scale), never O(mag) — across both
    safeguard backends."""
    mat = _normal_stack(m, 6, seed)
    adv = mat.at[0].set(mag)
    for backend in _SG_BACKENDS:
        reg = _registry_for(m, 1, backend)
        for name in _ROBUST:
            agg_clean, _ = _run_steps(reg[name], mat)
            agg_adv, _ = _run_steps(reg[name], adv)
            shift = float(jnp.linalg.norm(agg_adv["w"] - agg_clean["w"]))
            honest = float(jnp.linalg.norm(mat[1:], axis=1).max())
            assert np.isfinite(shift), (name, backend)
            assert shift <= 20.0 * honest + 1.0, (name, backend, shift)


@given(st.integers(2, 10), st.integers(0, 2 ** 31 - 1))
@settings(**SET)
def test_variance_attack_within_population_variance(m_half, seed):
    """The attack stays statistically plausible: byzantine coords lie
    within [mu - 3 sigma, mu + 3 sigma] of the honest population."""
    m = 2 * m_half
    key = jax.random.PRNGKey(seed)
    g = {"w": jax.random.normal(key, (m, 16))}
    byz = jnp.arange(m) < m_half // 2 + 1
    out, _ = atk.make_variance_attack(0.3)(g, byz, None, jnp.int32(0), key)
    gw = np.asarray(g["w"])[~np.asarray(byz)]
    mu, sd = gw.mean(0), gw.std(0) + 1e-9
    adv = np.asarray(out["w"])[0]
    assert (np.abs(adv - mu) <= 3.0 * sd + 1e-5).all()


# ---------------------------------------------------- hetero partitioner


@given(st.integers(1, 4), st.integers(1, 8), st.integers(0, 1000),
       st.floats(0.01, 50.0, allow_nan=False))
@settings(**SET)
def test_dirichlet_partitioner_exact_shapes(mm, per, seed, alpha):
    """Satellite (DESIGN.md §13): every worker shard has exactly B/m
    examples — sampling is with replacement against static quotas, so
    shapes never depend on how skewed the mixture is."""
    from repro.data import hetero as H
    m = 2 * mm                       # even m, s=2-compatible
    B = m * per
    key = jax.random.PRNGKey(seed)
    w = H.worker_mixtures(H.mixture_key(seed), alpha, m, 10)
    assert w.shape == (m, 10)
    np.testing.assert_allclose(np.asarray(w.sum(axis=1)), 1.0, atol=1e-5)
    labels = jax.random.randint(key, (B,), 0, 10)
    idx = H.dirichlet_indices(key, labels, w, m, per)
    assert idx.shape == (m, per) and idx.dtype == jnp.int32
    assert bool(((idx >= 0) & (idx < B)).all())


@given(st.integers(0, 1000), st.floats(0.05, 50.0, allow_nan=False),
       st.integers(2, 10))
@settings(deadline=None, max_examples=10)
def test_dirichlet_mixtures_preserve_global_marginal(seed, alpha, C):
    """E[pi_i] is uniform for the symmetric Dirichlet, so averaging the
    selection reweighting over workers preserves the pool's label
    marginal in expectation."""
    from repro.data import hetero as H
    w = H.worker_mixtures(H.mixture_key(seed), alpha, 800, C)
    np.testing.assert_allclose(np.asarray(w).mean(axis=0), 1.0 / C,
                               atol=0.08)


@given(st.integers(0, 200), st.integers(1, 5))
@settings(deadline=None, max_examples=10)
def test_dirichlet_one_hot_mixture_gives_pure_class_shards(seed, per):
    """A worker whose mixture is a one-hot on class c receives only
    class-c examples (whenever the pool contains that class)."""
    from repro.data import hetero as H
    C = 6
    key = jax.random.PRNGKey(seed)
    labels = jnp.concatenate([jnp.arange(C),                # all present
                              jax.random.randint(key, (3 * C,), 0, C)])
    w = jnp.eye(C, dtype=jnp.float32)                       # worker i = class i
    idx = H.dirichlet_indices(key, labels, w, C, per)
    picked = np.asarray(labels)[np.asarray(idx)]            # (C, per)
    np.testing.assert_array_equal(picked, np.arange(C)[:, None]
                                  * np.ones((1, per), int))


@given(st.integers(0, 500), st.integers(1, 5), st.integers(1, 4))
@settings(deadline=None, max_examples=10)
def test_dirichlet_alpha_inf_recovers_iid_split_bitexact(seed, mm, perm):
    """alpha -> inf (and alpha <= 0) recover the contiguous IID
    worker_split bit-for-bit — the sentinel and the Dirichlet limit
    agree, so IID campaign cells are unchanged by the hetero machinery."""
    from repro.data import hetero as H
    from repro.data import tasks
    from repro.data.pipeline import worker_split
    m, per = 2 * mm, 2 * perm
    task = tasks.make_teacher_task(d_in=6, d_hidden=8, n_classes=5)
    key = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0xDA7A), 0)
    iid = worker_split(tasks.teacher_batch(task, key, m * per), m)
    for alpha in (float("inf"), 0.0, -3.0):
        w = H.worker_mixtures(H.mixture_key(seed), alpha, m, 5)
        got = H.hetero_worker_batch(task, key, m * per, m,
                                    mode="dirichlet", weights=w,
                                    alpha=alpha)
        assert np.array_equal(np.asarray(got["x"]), np.asarray(iid["x"]))
        assert np.array_equal(np.asarray(got["y"]), np.asarray(iid["y"]))


# ------------------------------------------------ planted-saddle family


_saddle_kind = st.sampled_from(["saddle_quad", "saddle_chain"])
_gap = st.floats(0.05, 3.0, allow_nan=False, width=32)


@given(_saddle_kind, _gap, st.integers(0, 2 ** 31 - 1))
@settings(**SET)
def test_saddle_analytic_grad_matches_autodiff(kind, gap, seed):
    """The closed-form gradient is exactly jax.grad of the closed-form
    value, to f32 tolerance, across the whole (kind, gap, x) family."""
    from repro.data import saddle as sad
    task = sad.make_saddle_task(10, kind, seed=seed % 7)
    x = 2.0 * jax.random.normal(jax.random.PRNGKey(seed), (10,))
    want = jax.grad(lambda z: sad.saddle_value(task, z, gap))(x)
    np.testing.assert_allclose(np.asarray(sad.saddle_grad(task, x, gap)),
                               np.asarray(want), rtol=2e-4, atol=2e-5)


@given(_saddle_kind, _gap, st.integers(0, 2 ** 31 - 1))
@settings(**SET)
def test_saddle_min_eig_proxy_brackets_planted_minimum(kind, gap, seed):
    """At the planted saddle the Rayleigh proxy equals lambda_min = -gap
    exactly; everywhere it stays >= -gap (quartic curvature only adds)."""
    from repro.data import saddle as sad
    task = sad.make_saddle_task(10, kind, seed=seed % 5)
    at_saddle = float(sad.min_eig_proxy(task, sad.x_init(task)["x"], gap))
    assert at_saddle == pytest.approx(-gap, rel=1e-5)
    x = 3.0 * jax.random.normal(jax.random.PRNGKey(seed), (10,))
    assert float(sad.min_eig_proxy(task, x, gap)) >= -gap - 1e-5 * gap


@given(_saddle_kind, _gap, st.integers(0, 2 ** 31 - 1),
       st.integers(0, 2 ** 8 - 1))
@settings(**SET)
def test_saddle_escaped_invariant_under_symmetry(kind, gap, seed, bits):
    """The escape predicate is invariant under the family's symmetry
    group: any subset of per-stage reflections u_j -> -u_j plus any
    translation in the bulk complement."""
    from repro.data import saddle as sad
    task = sad.make_saddle_task(10, kind, seed=seed % 5)
    x = 1.5 * jax.random.normal(jax.random.PRNGKey(seed), (10,))
    u = task.dirs @ x
    signs = jnp.asarray([1.0 if (bits >> j) & 1 else -1.0
                         for j in range(task.k)], jnp.float32)
    reflected = x + task.dirs.T @ ((signs - 1.0) * u)
    v = jax.random.normal(jax.random.PRNGKey(seed ^ 0xB11C), (10,))
    v = v - task.dirs.T @ (task.dirs @ v)            # bulk component
    moved = reflected + 2.0 * v
    assert bool(sad.escaped(task, moved, gap)) == \
        bool(sad.escaped(task, x, gap))


@given(st.integers(0, 2 ** 16 - 1), st.integers(1, 4), st.integers(1, 4))
@settings(deadline=None, max_examples=10)
def test_saddle_noise_zero_mean_over_seeds(seed0, mm, per):
    """IID linear-noise model: worker noise has zero mean over seeds, so
    E[g_i] is the analytic gradient (SVRG's control variate cancels it
    exactly under anchoring)."""
    from repro.data import saddle as sad
    task = sad.make_saddle_task(6, "saddle_quad")
    m = 2 * mm
    total = np.zeros((6,))
    n = 200
    for s in range(n):
        b = sad.saddle_batch(task, sad.step_key(seed0 + s, 0),
                             m * per, m)
        total += np.asarray(b["eps"]).mean(axis=(0, 1))
    assert np.abs(total / n).max() < 5.0 / np.sqrt(n * m * per)


@given(stacks(m_min=4), st.integers(0, 2 ** 16 - 1))
@settings(**SET)
def test_zeta_sq_matches_numpy(arr, mask_bits):
    """tree_dissimilarity == mean_i||g_i - mean_mask||^2 over the mask."""
    from repro.data import hetero as H
    m = arr.shape[0]
    mask = np.array([(mask_bits >> i) & 1 for i in range(m)], dtype=bool)
    if not mask.any():
        mask[0] = True
    g = {"x": jnp.asarray(arr)}
    got = float(H.zeta_sq(g, jnp.asarray(mask)))
    flat = arr.reshape(m, -1).astype(np.float64)
    center = flat[mask].mean(axis=0)
    want = float(((flat[mask] - center) ** 2).sum(axis=1).mean())
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
