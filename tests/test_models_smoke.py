"""Per-architecture smoke tests (assignment requirement): reduced configs
(2 layers, d_model <= 512, <= 4 experts), one forward + one train step on
CPU, asserting output shapes and no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.configs.base import TrainConfig
from repro.core.safeguard import SafeguardConfig
from repro.models import transformer as T
from repro.optim import make_optimizer
from repro.train import init_train_state, make_train_step

ALL_ARCHS = C.ARCH_IDS + C.EXTRA_IDS
B, L = 2, 32


def make_batch(cfg, key, batch=B, seq=L):
    if cfg.embed_stub:
        return {"embeds": 0.1 * jax.random.normal(key, (batch, seq,
                                                        cfg.d_model)),
                "labels": jax.random.randint(key, (batch, seq), 0,
                                             cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (batch, seq), 0,
                                         cfg.vocab_size)}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_config_is_reduced(arch):
    cfg = C.get_smoke(arch)
    assert cfg.n_layers <= 3
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = C.get(arch)
    table = {
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "tinyllama-1.1b-swa": (22, 2048, 32, 4, 5632, 32000),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
    }
    nl, d, h, kv, ff, v = table[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == (nl, d, h, kv, ff, v)
    assert cfg.source


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = C.get_smoke(arch)
    params = T.init_params(cfg, rng)
    batch = make_batch(cfg, rng)
    inputs = batch.get("tokens", batch.get("embeds"))
    logits, _, aux = T.forward(params, cfg, inputs, mode="train")
    assert logits.shape == (B, L, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux["moe_aux"]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_safeguarded_train_step(arch, rng):
    cfg = C.get_smoke(arch)
    m = 4
    params = T.init_params(cfg, rng)
    opt = make_optimizer(TrainConfig(lr=0.01))
    sg_cfg = SafeguardConfig(m=m, T0=10, T1=20, threshold_floor=5.0)
    state = init_train_state(params, opt, sg_cfg=sg_cfg)
    step = make_train_step(lambda p, b: T.loss_fn(p, cfg, b), opt,
                           byz_mask=jnp.zeros((m,), bool), sg_cfg=sg_cfg)
    wb = jax.tree.map(
        lambda x: jnp.stack([x] * m), make_batch(cfg, rng, batch=2))
    new_state, metrics = step(state, wb)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(metrics["n_good"]) == m
    assert int(new_state.step) == 1
    # params actually moved
    moved = jax.tree.map(
        lambda a, b_: bool(jnp.any(a != b_)), state.params,
        new_state.params)
    assert any(jax.tree_util.tree_leaves(moved))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch, rng):
    cfg = C.get_smoke(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    params = T.init_params(cfg, rng)
    Lp, nd = 16, 4
    batch = make_batch(cfg, rng, seq=Lp + nd)
    seq = batch.get("tokens", batch.get("embeds"))
    full, _, _ = T.forward(params, cfg, seq, mode="train")
    last, cache = T.prefill(params, cfg, seq[:, :Lp], max_seq=Lp + nd)
    errs = [float(jnp.abs(last - full[:, Lp - 1]).max())]
    for i in range(nd):
        tok = seq[:, Lp + i:Lp + i + 1]
        lg, cache = T.decode_step(params, cfg, tok, cache)
        errs.append(float(jnp.abs(lg - full[:, Lp + i]).max()))
    assert max(errs) < 2e-4, errs


@pytest.mark.parametrize("arch", ["tinyllama-1.1b-swa", "recurrentgemma-2b",
                                  "mamba2-130m"])
def test_subquadratic_flag(arch):
    assert C.get(arch).sub_quadratic


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "granite-34b",
                                  "deepseek-v2-236b", "musicgen-medium"])
def test_full_attention_not_subquadratic(arch):
    assert not C.get(arch).sub_quadratic
