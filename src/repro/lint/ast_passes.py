"""Tier-1 AST passes: repo-specific trace-time contracts, checked
statically (DESIGN.md §16).

Rules (ids are stable; the catalog with per-rule motivation lives in
DESIGN.md):

  traced-branch   Python ``if``/``while``/ternary on a traced value
                  inside a scan/jit body (bakes the branch at trace
                  time — the knob-leak class behind the engine's
                  knobs-as-lanes design).
  host-cast       ``float()``/``int()``/``bool()``/``.item()`` on a
                  traced value inside a trace body (host sync /
                  ConcretizationTypeError at vmap time).
  np-in-trace     ``np.*`` called on a traced value inside a trace body
                  (silently materializes, breaks grad/vmap).
  key-reuse       a ``jax.random`` key consumed more than once in a
                  lexical scope, consumed inside a loop it was hoisted
                  out of, or split off and never consumed (stream
                  misalignment — the engine-vs-Trainer bit-identity
                  contract from PR 2/5).
  knob-literal    a knob-named parameter / dataclass field defaulted to
                  a bare numeric literal instead of referencing
                  ``DEFENSE_DEFAULTS``/``ADAPTIVE_DEFAULTS``.
  obs-key         an ``info[...]``/``metrics[...]``/``payload[...]``
                  key written in core/defenses.py, core/safeguard.py
                  or train/trainer.py that is not registered in
                  ``obs/schema.py`` (would raise SchemaError at trace
                  time — catch it before the campaign does).

Host-callback exemption: a function handed to ``jax.experimental.
io_callback`` / ``jax.pure_callback`` / ``jax.debug.callback`` executes
on the host even when defined inside a trace body, so the trace-body
rules do not apply within it (the enclosing body stays enforced; see
``tests/lint_fixtures/fx_host_callback_good.py``).
  scenario-hash   a ``Scenario`` field added/removed/re-defaulted
                  without updating the committed hash-treatment
                  declaration (silently re-keys or orphans stored
                  campaign cells).

Trace bodies are found statically: functions passed to jax transforms
(``jit``/``vmap``/``lax.scan``/``lax.cond``/...) or to the repo's own
``scan_trial``, functions with protocol names (``aggregate``, ``act``,
``observe``, ``step_fn``, ``body``, ``batch_fn``, ``held_fn``,
``trial``) nested inside a factory, and everything lexically nested
inside any of those."""

from __future__ import annotations

import ast
import json
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.lint.allowlist import inline_allows
from repro.lint.report import Violation

# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

JAX_TRANSFORMS = {
    "jit", "vmap", "pmap", "grad", "value_and_grad", "checkpoint",
    "scan", "cond", "while_loop", "fori_loop", "switch",
    "make_jaxpr", "scan_trial",
}

# host-side jax namespaces whose higher-order functions are NOT traces
# (jax.tree.map's callable runs eagerly)
_HOST_QUALIFIERS = {"tree", "tree_util", "np", "numpy"}


def _is_transform_call(chain: Tuple[str, ...]) -> bool:
    if not chain or chain[-1] not in JAX_TRANSFORMS and chain[-1] != "map":
        return False
    if len(chain) >= 2 and chain[-2] in _HOST_QUALIFIERS:
        return False
    if chain[-1] == "map":          # only lax.map traces its callable
        return len(chain) >= 2 and chain[-2] == "lax"
    return True

# nested functions with these names implement traced protocols even when
# the jax transform call sits in another module (Defense.aggregate is
# called from the jitted train step; Attack.act/observe likewise)
PROTOCOL_NAMES = {"aggregate", "act", "observe", "step_fn", "body",
                  "batch_fn", "held_fn", "trial", "power_step"}

# host-callback entry points: the callable handed as their first
# argument executes on the HOST (numpy, float(), file I/O are all legal
# there) even when it is defined inside a trace body — the live
# telemetry tap (DESIGN.md §17) is exactly this shape
HOST_CALLBACK_NAMES = {"io_callback", "pure_callback"}


def _is_host_callback_call(chain: Tuple[str, ...]) -> bool:
    if not chain:
        return False
    if chain[-1] in HOST_CALLBACK_NAMES:
        return True
    # jax.debug.callback / debug.callback (but not a bare `callback`)
    return chain[-1] == "callback" and len(chain) >= 2 \
        and chain[-2] == "debug"

STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}

RNG_SAMPLERS = {
    "bits", "normal", "uniform", "gumbel", "exponential", "laplace",
    "logistic", "cauchy", "beta", "gamma", "loggamma", "dirichlet",
    "poisson", "bernoulli", "categorical", "choice", "permutation",
    "randint", "truncated_normal", "rademacher", "ball", "maxwell",
    "multivariate_normal", "orthogonal", "t", "triangular", "weibull_min",
}
RNG_DERIVERS = {"split", "fold_in", "clone"}
RNG_CONSUMERS = RNG_SAMPLERS | RNG_DERIVERS


def _dotted(node: ast.AST) -> Tuple[str, ...]:
    """('jax','lax','scan') for jax.lax.scan; () when not a name chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def _param_names(fn: ast.AST) -> Set[str]:
    a = fn.args
    names = [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


class _Module:
    """Parsed module plus the derived maps every pass shares."""

    def __init__(self, path: Path, rel: str):
        self.path = path
        self.rel = rel
        self.source = path.read_text()
        self.lines = self.source.splitlines()
        self.syntax_error: Optional[Violation] = None
        self.parents: Dict[ast.AST, ast.AST] = {}
        try:
            self.tree = ast.parse(self.source, filename=rel)
        except SyntaxError as e:           # repro.lint replaces the old
            self.tree = ast.Module(body=[], type_ignores=[])
            self.syntax_error = Violation(   # compileall syntax gate
                "syntax-error", rel, e.lineno or 1, e.msg or "syntax error",
                col=(e.offset or 0))
            return
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node

    def allowed(self, lineno: int, rule: str) -> bool:
        if 1 <= lineno <= len(self.lines):
            return rule in inline_allows(self.lines[lineno - 1])
        return False

    def violation(self, rule: str, node: ast.AST, msg: str
                  ) -> Optional[Violation]:
        if self.allowed(node.lineno, rule):
            return None
        return Violation(rule, self.rel, node.lineno, msg,
                         col=node.col_offset + 1)


def load_modules(root: Path, paths: Iterable[Path]) -> List[_Module]:
    mods = []
    for p in sorted(paths):
        rel = str(p.relative_to(root)) if p.is_absolute() else str(p)
        mods.append(_Module(p if p.is_absolute() else root / p, rel))
    return mods


# ---------------------------------------------------------------------------
# trace-body discovery
# ---------------------------------------------------------------------------

def _function_defs(tree: ast.AST) -> Dict[str, List[ast.AST]]:
    defs: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)
    return defs


def trace_bodies(mod: _Module) -> List[ast.AST]:
    """All function/lambda nodes whose bodies execute under a trace."""
    defs = _function_defs(mod.tree)
    roots: Set[ast.AST] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            if not _is_transform_call(_dotted(node.func)):
                continue
            for arg in node.args:
                if isinstance(arg, ast.Lambda):
                    roots.add(arg)
                elif isinstance(arg, ast.Name):
                    for fn in defs.get(arg.id, ()):
                        roots.add(fn)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name in PROTOCOL_NAMES and isinstance(
                    mod.parents.get(node),
                    (ast.FunctionDef, ast.AsyncFunctionDef)):
                roots.add(node)
    # functions handed to host callbacks escape the trace: their bodies
    # (and anything nested in them) run host-side, so they are exempt —
    # the surrounding trace body stays enforced
    host: Set[ast.AST] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) \
                and _is_host_callback_call(_dotted(node.func)) \
                and node.args:
            target = node.args[0]
            if isinstance(target, ast.Lambda):
                host.add(target)
            elif isinstance(target, ast.Name):
                for fn in defs.get(target.id, ()):
                    host.add(fn)
    host_all: Set[ast.AST] = set()
    for fn in host:
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                host_all.add(node)
    # everything lexically nested inside a root is also a trace body
    bodies: Set[ast.AST] = set()
    for fn in roots:
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)) and node not in host_all:
                bodies.add(node)
    return sorted(bodies, key=lambda n: n.lineno)


# ---------------------------------------------------------------------------
# traced-value heuristics
# ---------------------------------------------------------------------------

def _expr_is_traced(node: ast.AST, taint: Set[str]) -> bool:
    """Direct use of a trace-body parameter (incl. attr/subscript chains
    rooted at one), minus static-structure attributes."""
    if isinstance(node, ast.Name):
        return node.id in taint
    if isinstance(node, ast.Attribute):
        if node.attr in STATIC_ATTRS:
            return False
        return _expr_is_traced(node.value, taint)
    if isinstance(node, ast.Subscript):
        return _expr_is_traced(node.value, taint)
    if isinstance(node, ast.BinOp):
        return (_expr_is_traced(node.left, taint)
                or _expr_is_traced(node.right, taint))
    if isinstance(node, ast.UnaryOp):
        return _expr_is_traced(node.operand, taint)
    return False


def _deep_traced(node: ast.AST, taint: Set[str]) -> bool:
    """Any tainted name anywhere in the subtree, skipping
    static-structure attribute accesses and len() calls."""
    if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
        return False
    if isinstance(node, ast.Call):
        chain = _dotted(node.func)
        if chain and chain[-1] in {"len", "isinstance", "hasattr",
                                   "getattr", "callable"}:
            return False
    if isinstance(node, ast.Name):
        return node.id in taint
    return any(_deep_traced(c, taint) for c in ast.iter_child_nodes(node))


def _test_is_traced(test: ast.AST, taint: Set[str]) -> bool:
    if isinstance(test, ast.BoolOp):
        return any(_test_is_traced(v, taint) for v in test.values)
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _test_is_traced(test.operand, taint)
    if isinstance(test, ast.Compare):
        # identity / membership tests are static at trace time (is None
        # sentinels, dict-key membership)
        if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
               for op in test.ops):
            return False
        return any(_expr_is_traced(o, taint)
                   for o in (test.left, *test.comparators))
    if isinstance(test, ast.Call):
        chain = _dotted(test.func)
        if chain and chain[-1] in {"isinstance", "hasattr", "len",
                                   "callable", "getattr"}:
            return False
        return any(_expr_is_traced(a, taint) for a in test.args)
    return _expr_is_traced(test, taint)


# ---------------------------------------------------------------------------
# pass: traced-branch / host-cast / np-in-trace
# ---------------------------------------------------------------------------

def check_trace_bodies(mod: _Module) -> List[Violation]:
    out: List[Violation] = []
    bodies = trace_bodies(mod)
    body_ids = {id(b) for b in bodies}
    for fn in bodies:
        taint = _param_names(fn)
        # closure capture: an enclosing trace body's params are traced
        # here too (the nested body is walked with its own taint, so
        # pruning below must not lose them)
        cur = fn
        while cur in mod.parents:
            cur = mod.parents[cur]
            if id(cur) in body_ids:
                taint |= _param_names(cur)
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        # nested defs are their own trace bodies: prune their subtrees
        # so every node is visited exactly once (same technique as
        # check_key_reuse)
        nested = [n for stmt in body for n in ast.walk(stmt)
                  if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                    ast.Lambda))]
        skip = {id(x) for sub in nested for x in ast.walk(sub)
                if x is not sub}
        for node in [n for stmt in body for n in ast.walk(stmt)
                     if id(n) not in skip]:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                if _test_is_traced(node.test, taint):
                    kind = ("while" if isinstance(node, ast.While) else
                            "ternary" if isinstance(node, ast.IfExp)
                            else "if")
                    v = mod.violation(
                        "traced-branch", node,
                        f"Python `{kind}` on a traced value inside a "
                        "trace body — the branch is baked in at trace "
                        "time; use jnp.where / lax.cond, or mark the "
                        "test `# lint: allow(traced-branch)` if it is "
                        "genuinely static")
                    if v:
                        out.append(v)
            elif isinstance(node, ast.Call):
                chain = _dotted(node.func)
                if (chain in (("float",), ("int",), ("bool",))
                        and node.args
                        and _deep_traced(node.args[0], taint)):
                    v = mod.violation(
                        "host-cast", node,
                        f"`{chain[0]}()` on a traced value inside a "
                        "trace body — concretizes the tracer; use "
                        "jnp.asarray / .astype")
                    if v:
                        out.append(v)
                elif (isinstance(node.func, ast.Attribute)
                      and node.func.attr in {"item", "tolist"}
                      and not node.args):
                    v = mod.violation(
                        "host-cast", node,
                        f"`.{node.func.attr}()` inside a trace body — "
                        "forces a host sync / fails under jit")
                    if v:
                        out.append(v)
                elif (chain[:1] in (("np",), ("numpy",)) and len(chain) > 1
                      and any(_deep_traced(a, taint) for a in node.args)):
                    v = mod.violation(
                        "np-in-trace", node,
                        f"`{'.'.join(chain)}` called on a traced value "
                        "inside a trace body — numpy materializes the "
                        "tracer; use the jnp equivalent")
                    if v:
                        out.append(v)
    return out


# ---------------------------------------------------------------------------
# pass: debugger (parity with the grep this analyzer replaced)
# ---------------------------------------------------------------------------

def check_debugger(mod: _Module) -> List[Violation]:
    out = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            chain = _dotted(node.func)
            if chain in (("breakpoint",), ("pdb", "set_trace")):
                v = mod.violation(
                    "debugger", node,
                    f"`{'.'.join(chain)}()` left in the tree")
                if v:
                    out.append(v)
    return out


# ---------------------------------------------------------------------------
# pass: key-reuse
# ---------------------------------------------------------------------------

def _is_rng_call(node: ast.AST, names: Iterable[str]) -> bool:
    if not isinstance(node, ast.Call):
        return False
    chain = _dotted(node.func)
    return (len(chain) >= 2 and chain[-2] == "random"
            and chain[-1] in names)


def _rng_key_params(fn: ast.AST) -> Set[str]:
    return {p for p in _param_names(fn)
            if p in {"key", "rng", "keys"} or p.endswith(("_key", "_rng"))}


def check_key_reuse(mod: _Module) -> List[Violation]:
    out: List[Violation] = []
    for fn in ast.walk(mod.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        # generation-aware tracking: a reassignment starts a new key
        # generation (`key = fold_in(key, t)` chains are one use each)
        gen: Dict[str, int] = {}
        assign_at: Dict[Tuple[str, int], Tuple[ast.AST, Set[ast.AST]]] = {}
        consumed: Dict[Tuple[str, int], List[ast.AST]] = {}

        def loops_of(node: ast.AST) -> Set[ast.AST]:
            anc, cur = set(), node
            while cur is not fn and cur in mod.parents:
                cur = mod.parents[cur]
                if isinstance(cur, (ast.For, ast.While)):
                    anc.add(cur)
            return anc

        def branch_path(node: ast.AST) -> Dict[int, int]:
            """{id(if-node): arm} for every enclosing If — two uses in
            different arms of one If are mutually exclusive."""
            path, cur = {}, node
            while cur is not fn and cur in mod.parents:
                parent = mod.parents[cur]
                if isinstance(parent, ast.If):
                    # cur is a *direct* child: the test, or a statement
                    # of one arm
                    if any(cur is s for s in parent.body):
                        path[id(parent)] = 0
                    elif any(cur is s for s in parent.orelse):
                        path[id(parent)] = 1
                cur = parent
            return path

        def may_coexecute(a: ast.AST, b: ast.AST) -> bool:
            pa, pb = branch_path(a), branch_path(b)
            return all(pa[k] == pb[k] for k in pa.keys() & pb.keys())

        for p in _rng_key_params(fn):
            gen[p] = 0
            assign_at[(p, 0)] = (fn, set())

        # nested defs get their own scope; exclude their bodies
        nested = [n for n in ast.walk(fn)
                  if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                  and n is not fn]
        skip = {id(x) for sub in nested for x in ast.walk(sub)
                if x is not sub}
        own = sorted(
            (n for n in ast.walk(fn) if id(n) not in skip
             and hasattr(n, "lineno")),
            key=lambda n: (n.lineno, n.col_offset))

        def consume(node: ast.Call) -> None:
            arg0 = node.args[0]
            if isinstance(arg0, ast.Name) and arg0.id in gen:
                consumed.setdefault((arg0.id, gen[arg0.id]), []).append(node)

        handled: Set[int] = set()
        for node in own:
            if isinstance(node, ast.Assign):
                is_rng_rhs = _is_rng_call(
                    node.value, {"split", "fold_in", "PRNGKey", "key",
                                 "wrap_key_data", "clone"})
                if is_rng_rhs and node.value.args:
                    consume(node.value)        # RHS reads the OLD gen
                    handled.add(id(node.value))
                for tgt in node.targets:
                    elts = tgt.elts if isinstance(tgt, ast.Tuple) else [tgt]
                    for e in elts:
                        if not isinstance(e, ast.Name):
                            continue
                        if is_rng_rhs and not e.id.startswith("_"):
                            gen[e.id] = gen.get(e.id, -1) + 1
                            assign_at[(e.id, gen[e.id])] = (
                                node, loops_of(node))
                        elif e.id in gen:      # non-rng rebind kills it
                            gen.pop(e.id)
            elif isinstance(node, ast.Call) and id(node) not in handled \
                    and _is_rng_call(node, RNG_CONSUMERS) and node.args:
                consume(node)

        for (name, g), uses in consumed.items():
            assign, assign_loops = assign_at[(name, g)]
            clash = next(
                ((a, b) for i, a in enumerate(uses) for b in uses[i + 1:]
                 if may_coexecute(a, b)), None)
            if clash is not None:
                v = mod.violation(
                    "key-reuse", clash[1],
                    f"rng key `{name}` consumed more than once in one "
                    f"scope (first at line {clash[0].lineno}) — split "
                    "it first; every key is consumed exactly once")
                if v:
                    out.append(v)
            for use in uses:
                if loops_of(use) - assign_loops:
                    v = mod.violation(
                        "key-reuse", use,
                        f"rng key `{name}` assigned outside a loop but "
                        "consumed inside it — every iteration reuses "
                        "the same stream; fold the loop index in")
                    if v:
                        out.append(v)

        # dead keys: split/fold products never read at all
        loads = {n.id for n in ast.walk(fn)
                 if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}
        for (name, g), (assign, _) in assign_at.items():
            if isinstance(assign, ast.Assign) and name not in loads:
                v = mod.violation(
                    "key-reuse", assign,
                    f"rng key `{name}` is split off but never consumed "
                    "— dead keys silently shift the stream layout; "
                    "name it `_...` if the slot is intentional")
                if v:
                    out.append(v)
    return out


# ---------------------------------------------------------------------------
# pass: knob-literal
# ---------------------------------------------------------------------------

_KNOB_SOURCES = ("DEFENSE_DEFAULTS", "ADAPTIVE_DEFAULTS")


def knob_names(root: Path) -> Set[str]:
    """Keys of DEFENSE_DEFAULTS / ADAPTIVE_DEFAULTS, read from the AST
    (self-maintaining: a new knob in either dict extends the rule)."""
    names: Set[str] = set()
    for rel in ("src/repro/core/defenses.py", "src/repro/core/attacks.py"):
        tree = ast.parse((root / rel).read_text())
        for node in ast.walk(tree):
            if (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id in _KNOB_SOURCES
                    and isinstance(node.value, ast.Dict)):
                for k in node.value.keys:
                    if isinstance(k, ast.Constant) and isinstance(
                            k.value, str):
                        names.add(k.value)
    return names


def _mentions_knob_source(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Name) and n.id in _KNOB_SOURCES
               for n in ast.walk(node))


def check_knob_literals(mod: _Module, knobs: Set[str]) -> List[Violation]:
    out: List[Violation] = []

    def flag(node: ast.AST, name: str, kind: str):
        v = mod.violation(
            "knob-literal", node,
            f"{kind} `{name}` defaults to a bare literal — single-source "
            "it from DEFENSE_DEFAULTS/ADAPTIVE_DEFAULTS (duplicated "
            "knob literals drift; PR 3/4 contract)")
        if v:
            out.append(v)

    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            a = node.args
            pos = [*a.posonlyargs, *a.args]
            for p, d in zip(pos[len(pos) - len(a.defaults):], a.defaults):
                if (p.arg in knobs
                        and isinstance(d, ast.Constant)
                        and isinstance(d.value, (int, float))
                        and not isinstance(d.value, bool)):
                    flag(d, p.arg, "parameter")
            for p, d in zip(a.kwonlyargs, a.kw_defaults):
                if (d is not None and p.arg in knobs
                        and isinstance(d, ast.Constant)
                        and isinstance(d.value, (int, float))
                        and not isinstance(d.value, bool)):
                    flag(d, p.arg, "parameter")
        elif isinstance(node, ast.ClassDef):
            for stmt in node.body:
                if (isinstance(stmt, ast.AnnAssign)
                        and isinstance(stmt.target, ast.Name)
                        and stmt.target.id in knobs
                        and stmt.value is not None
                        and isinstance(stmt.value, ast.Constant)
                        and isinstance(stmt.value.value, (int, float))
                        and not isinstance(stmt.value.value, bool)):
                    flag(stmt.value, stmt.target.id, "dataclass field")
    return out


# ---------------------------------------------------------------------------
# pass: obs-key
# ---------------------------------------------------------------------------

OBS_WRITER_FILES = ("src/repro/core/defenses.py",
                    "src/repro/core/safeguard.py",
                    "src/repro/train/trainer.py")


def registered_obs_keys(root: Path) -> Dict[str, Set[str]]:
    """{'info': {...}, 'metrics': {...}} parsed from obs/schema.py's
    registry assignments (AST-level, no import)."""
    tree = ast.parse((root / "src/repro/obs/schema.py").read_text())
    tables = {"INFO": "info", "METRICS": "metrics", "TAP": "tap"}
    out: Dict[str, Set[str]] = {"info": set(), "metrics": set(),
                                "tap": set()}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            target, value = node.targets[0], node.value
        elif isinstance(node, ast.AnnAssign) and isinstance(
                node.target, ast.Name) and node.value is not None:
            target, value = node.target, node.value
        else:
            continue
        if target.id in tables:
            surface = tables[target.id]
            for call in ast.walk(value):
                if (isinstance(call, ast.Call)
                        and _dotted(call.func)[-1:] == ("MetricSpec",)
                        and call.args
                        and isinstance(call.args[0], ast.Constant)):
                    out[surface].add(call.args[0].value)
    return out


def _loop_const_values(mod: _Module, name_node: ast.Name) -> List[str]:
    """If ``name_node`` is the target of an enclosing ``for k in
    ("a", "b"):`` loop, return the constant tuple elements."""
    cur = name_node
    while cur in mod.parents:
        cur = mod.parents[cur]
        if isinstance(cur, ast.For) and isinstance(cur.target, ast.Name) \
                and cur.target.id == name_node.id \
                and isinstance(cur.iter, (ast.Tuple, ast.List)):
            vals = [e.value for e in cur.iter.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, str)]
            if len(vals) == len(cur.iter.elts):
                return vals
    return []


def written_obs_keys(mod: _Module) -> List[Tuple[str, str, ast.AST]]:
    """(surface, key, node) for every statically-visible write into an
    ``info``/``metrics`` dict."""
    out: List[Tuple[str, str, ast.AST]] = []
    # `payload` is the tap surface's conventional dict name
    # (train.trainer.tap_payload builds it; keys must be TAP-registered)
    surface_of = {"info": "info", "metrics": "metrics",
                  "payload": "tap"}
    for node in ast.walk(mod.tree):
        # info["k"] = ... / metrics["k"] = ...
        if isinstance(node, ast.Subscript) and isinstance(
                node.ctx, ast.Store) and isinstance(node.value, ast.Name) \
                and node.value.id in surface_of:
            surface = surface_of[node.value.id]
            sl = node.slice
            if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
                out.append((surface, sl.value, node))
            elif isinstance(sl, ast.Name):
                for k in _loop_const_values(mod, sl):
                    out.append((surface, k, node))
        # info = {...} / metrics = {...} dict literals (plain or
        # annotated assignment — `payload: Dict[...] = {...}`)
        elif (isinstance(node, ast.Assign) and len(node.targets) == 1
              and isinstance(node.targets[0], ast.Name)
              and node.targets[0].id in surface_of
              and isinstance(node.value, ast.Dict)) \
                or (isinstance(node, ast.AnnAssign)
                    and isinstance(node.target, ast.Name)
                    and node.target.id in surface_of
                    and isinstance(node.value, ast.Dict)):
            name = (node.targets[0].id if isinstance(node, ast.Assign)
                    else node.target.id)
            surface = surface_of[name]
            for k in node.value.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    out.append((surface, k.value, k))
        # return {...} from helpers named *_info
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name.endswith("_info"):
            for ret in ast.walk(node):
                if isinstance(ret, ast.Return) and isinstance(
                        ret.value, ast.Dict):
                    for k in ret.value.keys:
                        if isinstance(k, ast.Constant) and isinstance(
                                k.value, str):
                            out.append(("info", k.value, k))
        # info.update({...}) / metrics.update({...}) with a dict literal
        elif isinstance(node, ast.Call) and isinstance(
                node.func, ast.Attribute) and node.func.attr == "update" \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id in surface_of \
                and node.args and isinstance(node.args[0], ast.Dict):
            surface = surface_of[node.func.value.id]
            for k in node.args[0].keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    out.append((surface, k.value, k))
    return out


def check_obs_keys(mod: _Module, registered: Dict[str, Set[str]]
                   ) -> List[Violation]:
    out: List[Violation] = []
    for surface, key, node in written_obs_keys(mod):
        if key not in registered[surface]:
            v = mod.violation(
                "obs-key", node,
                f"{surface} key {key!r} is written here but not "
                "registered in repro.obs.schema — the trace-time "
                "validator will raise SchemaError; register a "
                "MetricSpec first (PR 7 contract)")
            if v:
                out.append(v)
    return out


# ---------------------------------------------------------------------------
# pass: scenario-hash
# ---------------------------------------------------------------------------

SCENARIO_FILE = "src/repro/campaign/scenario.py"


def scenario_fields(root: Path) -> Dict[str, Dict[str, Optional[str]]]:
    """field -> {'default': unparsed default or None, 'id': treatment}
    parsed from the Scenario dataclass.  Fields without a default are
    'always' in scenario_id; defaulted fields are 'when-non-default'."""
    tree = ast.parse((root / SCENARIO_FILE).read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "Scenario":
            fields: Dict[str, Dict[str, Optional[str]]] = {}
            for stmt in node.body:
                if isinstance(stmt, ast.AnnAssign) and isinstance(
                        stmt.target, ast.Name):
                    default = (ast.unparse(stmt.value)
                               if stmt.value is not None else None)
                    fields[stmt.target.id] = {
                        "default": default,
                        "id": ("always" if default is None
                               else "when-non-default"),
                    }
            return fields
    raise RuntimeError(f"Scenario dataclass not found in {SCENARIO_FILE}")


def check_scenario_hash(root: Path, baseline_path: Path
                        ) -> List[Violation]:
    current = scenario_fields(root)
    if not baseline_path.exists():
        return [Violation(
            "scenario-hash", SCENARIO_FILE, 1,
            f"hash-treatment declaration {baseline_path.name} is "
            "missing — run `python -m repro.lint --update-baselines`")]
    declared = json.loads(baseline_path.read_text())["fields"]
    out: List[Violation] = []
    for name, spec in current.items():
        if name not in declared:
            out.append(Violation(
                "scenario-hash", SCENARIO_FILE, 1,
                f"new Scenario field `{name}` has no declared hash "
                "treatment — a defaulted field joins scenario_id only "
                "when non-default (stored cells keep their keys); "
                "confirm that is what you want, then run `python -m "
                "repro.lint --update-baselines`"))
        elif declared[name] != spec:
            out.append(Violation(
                "scenario-hash", SCENARIO_FILE, 1,
                f"Scenario field `{name}` changed its default "
                f"({declared[name]['default']!r} -> "
                f"{spec['default']!r}) — this re-keys every stored "
                "cell that pinned the old default; update the "
                "declaration with --update-baselines after migrating "
                "the store"))
    for name in declared:
        if name not in current:
            out.append(Violation(
                "scenario-hash", SCENARIO_FILE, 1,
                f"Scenario field `{name}` was removed but is still "
                "declared — stored cells that set it are now "
                "unreachable; clean up with --update-baselines"))
    return out
