"""Tier-2 jaxpr-level passes: abstract-trace the campaign programs and
check structural invariants (DESIGN.md §16).

Rules:

  knob-structure  a vmappable knob leaked into program structure: the
                  jaxpr of ``make_trial_fn`` differs across scenario
                  variants that share ``batch_key`` (the recompilation
                  class — every such leak multiplies campaign compile
                  count by the axis length).
  jaxpr-drift     a program's jaxpr hash moved off the committed
                  baseline (structure changed; regenerate with
                  ``--update-baselines`` after review).
  rng-drift       a program's rng-consumption signature (primitive ->
                  count) moved off the committed baseline (stream
                  layout changed; engine-vs-Trainer bit-identity and
                  stored campaign cells are keyed to it).
  f64             a float64 value appears in a traced program (x64 is
                  off repo-wide; a promotion means a host float leaked
                  into a trace).
  sqrt-diff       an unclamped ``sqrt(sub(...))`` chain in a traced
                  program — the PR-3 NaN class; decision-site sqrts
                  must clamp (``jnp.maximum(sqdist, 0.0)``).

Programs are the deduped ``batch_key`` groups of the committed
campaigns (table1/defense/hetero/saddle/smoke) at quick depth — the
same program set CI smokes execute, but here only *traced* (~1s per
program, no compile, no run)."""

from __future__ import annotations

import hashlib
import json
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.lint.report import Violation

QUICK_STEPS = 40
CAMPAIGN_NAMES = ("table1", "defense", "hetero", "saddle", "smoke",
                  "live")
ENGINE_FILE = "src/repro/campaign/engine.py"

# knob axes probed for structure leaks: (scenario field, variant value).
# Variants stay inside each knob's validated range and differ from every
# campaign default so the probe is never a no-op.
KNOB_VARIANTS: Dict[str, float] = {
    "attack_scale": 3.5,
    "threshold_floor": 0.7,
    "threshold_scale": 1.9,
    "clip_tau": 2.5,
    "clip_beta": 0.8,
    "adapt_init": 0.3,
    "adapt_rate": 1.11,
    "adapt_down": 0.6,
    "adapt_target": 0.7,
    "hetero_alpha": 2.0,
    "hetero_shift": 0.9,
    "saddle_gap": 0.8,
    "noise_r": 0.02,
    "escape_nu": 0.2,
    "escape_thresh": 0.05,
    "seed": 7,
}

RNG_PRIMITIVES = ("random_seed", "random_wrap", "random_unwrap",
                  "random_split", "random_fold_in", "random_bits",
                  "threefry2x32")

# unary structural ops a value passes through unchanged on its way into
# a sqrt — walked through when hunting the producing arithmetic op
_PASS_THROUGH = {"convert_element_type", "copy", "broadcast_in_dim",
                 "squeeze", "reshape", "slice", "stop_gradient"}


# ---------------------------------------------------------------------------
# program enumeration + tracing
# ---------------------------------------------------------------------------

def campaign_programs() -> List[Tuple[str, object]]:
    """(label, representative scenario) per unique ``batch_key`` across
    the committed campaigns, first campaign to produce a key wins."""
    from repro.campaign import engine
    from repro.campaign.run import CAMPAIGNS

    programs: Dict[tuple, Tuple[str, object]] = {}
    for name in CAMPAIGN_NAMES:
        for group in engine.group_scenarios(CAMPAIGNS[name](1, QUICK_STEPS)):
            key = engine.batch_key(group[0])
            if key not in programs:
                s = group[0]
                label = (f"{name}/{s.task}/{s.attack}/{s.defense}"
                         f"/h={s.hetero or 'iid'}/p={s.perturb or 'none'}")
                # several groups can share the readable part (e.g. the
                # two guard modes); disambiguate with the key hash
                h = hashlib.sha256(repr(key).encode()).hexdigest()[:8]
                programs[key] = (f"{label}#{h}", s)
    return sorted(programs.values(), key=lambda kv: kv[0])


def trace_program(scenario, make_fn: Optional[Callable] = None):
    """ClosedJaxpr of the trial program for one scenario (lane 0 knob
    values as the abstract inputs — values never enter the jaxpr)."""
    import jax
    from repro.campaign import engine

    knobs = {k: v[0] for k, v in engine.stack_knobs([scenario]).items()}
    fn = (make_fn or engine.make_trial_fn)(scenario)
    return jax.make_jaxpr(fn)(knobs)


def jaxpr_hash(closed) -> str:
    return hashlib.sha256(str(closed).encode()).hexdigest()[:16]


def _walk_jaxprs(jaxpr):
    """Yield every (sub)jaxpr reachable through eqn params (scan bodies,
    cond branches, pjit-lowered calls)."""
    seen = set()
    stack = [jaxpr]
    while stack:
        jx = stack.pop()
        if id(jx) in seen:
            continue
        seen.add(id(jx))
        yield jx
        for eqn in jx.eqns:
            for val in eqn.params.values():
                for sub in (val if isinstance(val, (tuple, list))
                            else (val,)):
                    inner = getattr(sub, "jaxpr", None)
                    if inner is not None:
                        stack.append(inner)
                    elif hasattr(sub, "eqns"):
                        stack.append(sub)


def rng_counts(closed) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for jx in _walk_jaxprs(closed.jaxpr):
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if name in RNG_PRIMITIVES:
                counts[name] = counts.get(name, 0) + 1
    return dict(sorted(counts.items()))


# ---------------------------------------------------------------------------
# jaxpr walks: f64 + unclamped sqrt-of-difference
# ---------------------------------------------------------------------------

def find_f64(closed, label: str) -> List[Violation]:
    # one violation per program: the first f64-producing eqn names the
    # leak; the rest are downstream of it
    for jx in _walk_jaxprs(closed.jaxpr):
        for eqn in jx.eqns:
            for var in eqn.outvars:
                aval = getattr(var, "aval", None)
                if aval is not None and str(getattr(aval, "dtype", ""),
                                            ) == "float64":
                    return [Violation(
                        "f64", ENGINE_FILE, 1,
                        f"program {label}: `{eqn.primitive.name}` "
                        "produces float64 — x64 is off repo-wide, so a "
                        "host double leaked into the trace")]
    return []


def find_unclamped_sqrt(closed, label: str) -> List[Violation]:
    out = []
    for jx in _walk_jaxprs(closed.jaxpr):
        producer = {}
        for eqn in jx.eqns:
            for var in eqn.outvars:
                producer[var] = eqn
        for eqn in jx.eqns:
            if eqn.primitive.name != "sqrt":
                continue
            def produced_by(var):
                # Literal invars (unhashable) have no producer eqn
                return None if hasattr(var, "val") else producer.get(var)

            src = eqn.invars[0]
            for _ in range(8):       # walk through pass-through unaries
                p = produced_by(src)
                if p is None or p.primitive.name not in _PASS_THROUGH:
                    break
                src = p.invars[0]
            p = produced_by(src)
            if p is not None and p.primitive.name == "sub":
                out.append(Violation(
                    "sqrt-diff", ENGINE_FILE, 1,
                    f"program {label}: sqrt fed directly by a "
                    "subtraction — rounding can drive the operand "
                    "negative and NaN-poison the trial (PR-3 class); "
                    "clamp with jnp.maximum(x, 0.0) first"))
        del producer
    return out


# ---------------------------------------------------------------------------
# knob-structure invariance (the recompilation detector)
# ---------------------------------------------------------------------------

def relevant_knobs(scenario) -> List[str]:
    """Knob axes the program actually consumes — probing a knob the
    scenario never reads cannot detect a leak, so the invariance check
    skips it (keeps the probe budget ~5 traces per program)."""
    knobs = ["seed", "attack_scale"]
    if scenario.defense.startswith("safeguard"):
        knobs += ["threshold_floor", "threshold_scale"]
    if "clip" in scenario.defense or "bucket" in scenario.defense:
        knobs += ["clip_tau", "clip_beta"]
    if scenario.attack.startswith(("adaptive", "oscillating", "threshold",
                                   "saddle")):
        # all four controller knobs enter through one traced path;
        # probing two keeps the budget without losing the detector
        knobs += ["adapt_init", "adapt_rate"]
    if scenario.hetero == "dirichlet":
        knobs.append("hetero_alpha")
    elif scenario.hetero == "shift":
        knobs.append("hetero_shift")
    if scenario.task.startswith("saddle"):
        knobs += ["saddle_gap", "noise_r"]
    if scenario.perturb == "sgd_escape":
        knobs += ["escape_nu", "escape_thresh"]
    return [k for k in knobs if k in KNOB_VARIANTS]


def check_knob_invariance(scenario, label: str,
                          make_fn: Optional[Callable] = None,
                          knobs: Optional[Sequence[str]] = None,
                          base_hash: Optional[str] = None
                          ) -> List[Violation]:
    """Re-trace ``scenario`` with each probed knob replaced by a variant
    value and assert the jaxpr hash is unchanged.  Variants that change
    ``batch_key`` (legit program-structure knobs, e.g. ``n_byz`` for a
    static-n defense) are skipped — those are *supposed* to recompile."""
    import dataclasses

    from repro.campaign import engine

    base_key = engine.batch_key(scenario)
    if base_hash is None:
        base_hash = jaxpr_hash(trace_program(scenario, make_fn))
    out: List[Violation] = []
    probe = relevant_knobs(scenario) if knobs is None else knobs
    for field in probe:
        variant = KNOB_VARIANTS[field]
        if getattr(scenario, field, None) == variant:
            continue
        try:
            alt = dataclasses.replace(scenario, **{field: variant})
        except (TypeError, ValueError):
            continue
        if engine.batch_key(alt) != base_key:
            continue
        if jaxpr_hash(trace_program(alt, make_fn)) != base_hash:
            out.append(Violation(
                "knob-structure", ENGINE_FILE, 1,
                f"program {label}: knob `{field}` leaked into program "
                f"structure — the jaxpr changes when {field}="
                f"{variant}, so every vmap lane of this axis "
                "recompiles; thread the knob through the traced "
                "`knobs` dict instead of baking it in"))
    return out


# ---------------------------------------------------------------------------
# baseline orchestration
# ---------------------------------------------------------------------------

BASELINE_DIR = Path(__file__).parent / "baselines"
JAXPR_BASELINE = BASELINE_DIR / "jaxpr_hashes.json"
RNG_BASELINE = BASELINE_DIR / "rng_counts.json"


def _jax_version() -> str:
    import jax
    return jax.__version__

# probe one representative per campaign for knob invariance (all
# programs get hash+rng+walk checks; the invariance probe re-traces
# once per relevant knob, so it runs on a spread instead of all 70+);
# per campaign, pick the program consuming the most knob axes
def _probe_labels(programs: Sequence[Tuple[str, object]]) -> List[str]:
    best: Dict[str, Tuple[int, str]] = {}
    for lab, s in programs:
        campaign = lab.split("/", 1)[0]
        score = len(relevant_knobs(s))
        if campaign not in best or score > best[campaign][0]:
            best[campaign] = (score, lab)
    return [lab for _, lab in best.values()]


def run_tier2(update_baselines: bool = False,
              with_invariance: bool = True,
              progress: Optional[Callable[[str], None]] = None
              ) -> List[Violation]:
    programs = campaign_programs()
    probes = set(_probe_labels(programs)) if with_invariance else set()

    hashes: Dict[str, str] = {}
    rng: Dict[str, Dict[str, int]] = {}
    out: List[Violation] = []
    for lab, scenario in programs:
        if progress:
            progress(lab)
        closed = trace_program(scenario)
        hashes[lab] = jaxpr_hash(closed)
        rng[lab] = rng_counts(closed)
        out.extend(find_f64(closed, lab))
        out.extend(find_unclamped_sqrt(closed, lab))
        if lab in probes:
            out.extend(check_knob_invariance(scenario, lab,
                                             base_hash=hashes[lab]))

    if update_baselines:
        BASELINE_DIR.mkdir(parents=True, exist_ok=True)
        version = _jax_version()
        JAXPR_BASELINE.write_text(json.dumps(
            {"jax": version, "programs": hashes}, indent=1) + "\n")
        RNG_BASELINE.write_text(json.dumps(
            {"jax": version, "programs": rng}, indent=1) + "\n")
        return out

    out.extend(_diff_baseline(
        JAXPR_BASELINE, hashes, "jaxpr-drift",
        "program structure changed — if intended, regenerate with "
        "`python -m repro.lint --update-baselines` and review the "
        "diff"))
    out.extend(_diff_baseline(
        RNG_BASELINE, rng, "rng-drift",
        "rng-consumption signature changed — the stream layout is a "
        "bit-identity contract (PR 2/5); if intended, regenerate with "
        "--update-baselines"))
    return out


def _diff_baseline(path: Path, current: Dict, rule: str, hint: str
                   ) -> List[Violation]:
    rel = f"src/repro/lint/baselines/{path.name}"
    if not path.exists():
        return [Violation(rule, rel, 1,
                          "baseline file missing — run `python -m "
                          "repro.lint --update-baselines`")]
    data = json.loads(path.read_text())
    pinned, pinned_jax = data["programs"], data["jax"]
    out = []
    for lab, val in current.items():
        if lab not in pinned:
            out.append(Violation(rule, rel, 1,
                                 f"new program {lab} has no pinned "
                                 f"baseline — {hint}"))
        elif pinned[lab] != val:
            out.append(Violation(rule, rel, 1,
                                 f"program {lab}: {pinned[lab]} -> "
                                 f"{val}; {hint}"))
    for lab in pinned:
        if lab not in current:
            out.append(Violation(rule, rel, 1,
                                 f"pinned program {lab} no longer "
                                 f"exists — {hint}"))
    # jaxpr pretty-printing and lowering move between jax releases, so
    # under a different jax every hash shifts at once — that is version
    # skew, not a repo regression; report it as one actionable line
    # instead of a per-program avalanche
    if out and pinned_jax != _jax_version():
        return [Violation(
            rule, rel, 1,
            f"{len(out)} program(s) differ from the baseline, but the "
            f"baseline was generated under jax {pinned_jax} and this "
            f"run uses jax {_jax_version()} — rerun under jax "
            f"{pinned_jax} (the version CI pins), or regenerate with "
            "--update-baselines if the repo is moving versions")]
    return out
