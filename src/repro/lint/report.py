"""Violation records and the ``file:line: RULE message`` report format.

Every lint pass — AST (tier 1) and jaxpr (tier 2) — reports findings as
:class:`Violation` records.  The formatting contract is one line per
finding::

    src/repro/core/defenses.py:142:8: knob-literal clip_tau defaults to
        a bare literal 1.0 ...

which editors and CI annotate directly.  Tier-2 findings anchor to the
source location that *defines* the program under analysis (the campaign
builder or the baseline file) so every report line is clickable."""

from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True)
class Violation:
    """One lint finding.

    ``rule`` is the stable rule id (DESIGN.md §16 catalog), ``path`` is
    repo-relative, ``line``/``col`` are 1-based (col 0 when unknown)."""
    rule: str
    path: str
    line: int
    message: str
    col: int = 0

    def format(self) -> str:
        loc = f"{self.path}:{self.line}"
        if self.col:
            loc += f":{self.col}"
        return f"{loc}: {self.rule} {self.message}"


def render(violations: List[Violation]) -> str:
    """Stable, sorted report: by path, then line, then rule."""
    ordered = sorted(violations,
                     key=lambda v: (v.path, v.line, v.col, v.rule))
    return "\n".join(v.format() for v in ordered)
