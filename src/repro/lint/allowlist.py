"""Allowlist for intentional lint exceptions.

Two mechanisms, both explicit and reviewable:

* the committed file ``lint-allowlist.txt`` at the repo root — one
  entry per line::

      <rule-id>  <path-suffix>  [message substring]

  An entry suppresses a violation when the rule id matches, the
  violation path ends with the path suffix, and (if given) the message
  contains the substring.  Blank lines and ``#`` comments are ignored.

* an inline ``# lint: allow(<rule-id>)`` trailer on the flagged source
  line, for cases local enough that the file entry would just restate
  the line number.

Unused file entries are themselves reported (``stale-allow``) so the
allowlist can only shrink back to reality, never accrete.  The CLI
applies stale detection only on full (``--tier all``) runs: a partial
run cannot tell an unused entry from one whose tier didn't run."""

from __future__ import annotations

import dataclasses
import re
from pathlib import Path
from typing import List, Tuple

from repro.lint.report import Violation

DEFAULT_NAME = "lint-allowlist.txt"

_INLINE = re.compile(r"#\s*lint:\s*allow\(([a-z0-9-]+(?:\s*,\s*[a-z0-9-]+)*)\)")


@dataclasses.dataclass
class AllowEntry:
    rule: str
    path_suffix: str
    substring: str
    lineno: int           # line in the allowlist file (for stale reports)
    used: bool = False

    def matches(self, v: Violation) -> bool:
        return (v.rule == self.rule
                and v.path.endswith(self.path_suffix)
                and (self.substring in v.message))


class Allowlist:
    def __init__(self, entries: List[AllowEntry], path: str):
        self.entries = entries
        self.path = path

    @classmethod
    def load(cls, root: Path) -> "Allowlist":
        path = root / DEFAULT_NAME
        entries: List[AllowEntry] = []
        if path.exists():
            for i, raw in enumerate(path.read_text().splitlines(), 1):
                line = raw.strip()
                if not line or line.startswith("#"):
                    continue
                parts = line.split(None, 2)
                if len(parts) < 2:
                    continue
                rule, suffix = parts[0], parts[1]
                sub = parts[2] if len(parts) > 2 else ""
                entries.append(AllowEntry(rule, suffix, sub, i))
        return cls(entries, str(path))

    def filter(self, violations: List[Violation]
               ) -> Tuple[List[Violation], List[Violation]]:
        """Split into (kept, suppressed); mark entries used."""
        kept, suppressed = [], []
        for v in violations:
            hit = next((e for e in self.entries if e.matches(v)), None)
            if hit is not None:
                hit.used = True
                suppressed.append(v)
            else:
                kept.append(v)
        return kept, suppressed

    def stale_entries(self) -> List[Violation]:
        return [Violation("stale-allow", self.path, e.lineno,
                          f"allowlist entry '{e.rule} {e.path_suffix}"
                          f"{' ' + e.substring if e.substring else ''}' "
                          "matched nothing — remove it")
                for e in self.entries if not e.used]


def inline_allows(source_line: str) -> List[str]:
    """Rule ids allowed by an inline ``# lint: allow(...)`` trailer."""
    m = _INLINE.search(source_line)
    if not m:
        return []
    return [r.strip() for r in m.group(1).split(",")]
