"""``python -m repro.lint`` — run the two-tier analyzer.

Exit 0 when the tree is clean, 1 with one ``file:line: rule message``
report line per finding otherwise.  ``--update-baselines`` regenerates
the committed tier-2 baselines (jaxpr hashes, rng signatures) and the
Scenario hash-treatment declaration — do that only after reviewing why
they moved."""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import List, Tuple

from repro.lint import ast_passes, jaxpr_passes
from repro.lint.allowlist import Allowlist
from repro.lint.report import Violation, render

SCAN_DIRS = ("src", "tests", "benchmarks")
EXCLUDE_PARTS = {"lint_fixtures", "__pycache__", ".git"}

SCENARIO_BASELINE = jaxpr_passes.BASELINE_DIR / "scenario_fields.json"


def _python_files(root: Path) -> List[Path]:
    files: List[Path] = []
    for d in SCAN_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            if not EXCLUDE_PARTS.intersection(p.parts):
                files.append(p)
    return files


def run_tier1(root: Path) -> List[Violation]:
    mods = ast_passes.load_modules(root, _python_files(root))
    knobs = ast_passes.knob_names(root)
    registered = ast_passes.registered_obs_keys(root)
    out: List[Violation] = []
    for mod in mods:
        if mod.syntax_error is not None:
            out.append(mod.syntax_error)
            continue
        out.extend(ast_passes.check_trace_bodies(mod))
        out.extend(ast_passes.check_debugger(mod))
        # tests deliberately feed two implementations the same key for
        # A/B determinism, so the stream-layout rule scopes to shipped
        # code (DESIGN.md §16)
        if not mod.rel.startswith("tests/"):
            out.extend(ast_passes.check_key_reuse(mod))
        out.extend(ast_passes.check_knob_literals(mod, knobs))
        if mod.rel in ast_passes.OBS_WRITER_FILES:
            out.extend(ast_passes.check_obs_keys(mod, registered))
    out.extend(ast_passes.check_scenario_hash(root, SCENARIO_BASELINE))
    return out


def apply_allowlist(violations: List[Violation], allow: Allowlist,
                    tier: str) -> Tuple[List[Violation], List[Violation]]:
    """(kept, suppressed) after the allowlist.  Stale detection needs
    the full violation set: a partial run (e.g. CI-style ``--tier 2``)
    cannot tell an unused entry from one whose tier simply didn't run,
    so only ``--tier all`` may call entries stale."""
    kept, suppressed = allow.filter(violations)
    if tier == "all":
        kept.extend(allow.stale_entries())
    return kept, suppressed


def _update_scenario_baseline(root: Path) -> None:
    fields = ast_passes.scenario_fields(root)
    SCENARIO_BASELINE.parent.mkdir(parents=True, exist_ok=True)
    SCENARIO_BASELINE.write_text(
        json.dumps({"fields": fields}, indent=1) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="JAX-aware static analysis for this repo's "
                    "trace-time contracts (DESIGN.md §16)")
    ap.add_argument("--tier", choices=["1", "2", "all"], default="all",
                    help="1 = AST passes only (fast); 2 = jaxpr passes "
                         "only; all = both (default)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detected from this "
                         "package's location)")
    ap.add_argument("--update-baselines", action="store_true",
                    help="regenerate tier-2 baselines + the Scenario "
                         "hash declaration instead of diffing them")
    ap.add_argument("--no-invariance", action="store_true",
                    help="skip the knob-invariance probes (the most "
                         "expensive tier-2 check)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress progress output")
    args = ap.parse_args(argv)

    root = Path(args.root) if args.root else \
        Path(__file__).resolve().parents[3]
    t0 = time.time()

    if args.update_baselines:
        _update_scenario_baseline(root)

    violations: List[Violation] = []
    if args.tier in ("1", "all"):
        violations.extend(run_tier1(root))
    if args.tier in ("2", "all"):
        progress = None if args.quiet else (
            lambda lab: print(f"lint: tracing {lab}", file=sys.stderr))
        violations.extend(jaxpr_passes.run_tier2(
            update_baselines=args.update_baselines,
            with_invariance=not args.no_invariance,
            progress=progress))

    kept, suppressed = apply_allowlist(
        violations, Allowlist.load(root), args.tier)

    wall = time.time() - t0
    if kept:
        print(render(kept))
        print(f"repro.lint: {len(kept)} violation(s) "
              f"({len(suppressed)} allowlisted) in {wall:.1f}s",
              file=sys.stderr)
        return 1
    if not args.quiet:
        print(f"repro.lint: clean ({len(suppressed)} allowlisted, "
              f"tier={args.tier}, {wall:.1f}s)", file=sys.stderr)
    return 0
