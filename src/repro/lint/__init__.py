"""repro.lint — JAX-aware static analysis for the repo's trace-time
contracts (DESIGN.md §16).

Tier 1: AST passes (traced-branch, host-cast, np-in-trace, key-reuse,
knob-literal, obs-key, scenario-hash).  Tier 2: jaxpr-level passes over
the campaign programs (knob-structure invariance, jaxpr/rng baselines,
f64 + unclamped-sqrt walks).  Run as ``python -m repro.lint``."""

from repro.lint.report import Violation, render  # noqa: F401
