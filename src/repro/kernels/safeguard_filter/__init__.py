from repro.kernels.safeguard_filter.ops import pairwise_sqdist  # noqa: F401
from repro.kernels.safeguard_filter import ref                  # noqa: F401
