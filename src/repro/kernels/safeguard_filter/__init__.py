from repro.kernels.safeguard_filter.ops import (  # noqa: F401
    fused_accumulate_sqdist, pairwise_sqdist)
from repro.kernels.safeguard_filter import ref                  # noqa: F401
