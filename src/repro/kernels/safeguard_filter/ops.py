"""Jit-able wrappers for the safeguard flat-buffer kernels: handle ragged
d (zero-pad to a lane multiple — zeros do not change distances or the
accumulate), worker counts that are not sublane-aligned, and the d-tile
choice.  Under the CPU interpreter the emulator's per-grid-step cost (not
VMEM) is the overhead, so the wrappers run ONE whole-row block and skip
the TPU alignment padding entirely; compiled TPU runs get 512-wide MXU
tiles and sublane-aligned rows."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.safeguard_filter.kernel import (
    fused_accumulate_sqdist_kernel, pairwise_sqdist_kernel)

_LANE = 128


def _pick_block(d: int, block_d, interpret: bool) -> int:
    """Largest MXU-aligned tile that divides d; the whole row when
    interpreting."""
    if block_d is not None:
        return min(block_d, d)
    if interpret:
        return d
    for bd in (512, 256, _LANE):
        if d % bd == 0:
            return bd
    return d


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def pairwise_sqdist(a, *, block_d: int = 512, interpret: bool = True):
    """a: (m, d) any dtype -> (m, m) f32 squared distances.

    ``block_d=None`` picks the tile automatically (one whole-row block
    under the interpreter)."""
    m, d = a.shape
    pad_m = 0 if interpret else (-m) % 8     # TPU sublane multiple
    if block_d is None:
        bd = _pick_block(d if interpret else d + (-d) % _LANE, None,
                         interpret)
    else:
        bd = min(block_d, max(_LANE, _LANE * ((d + _LANE - 1) // _LANE)))
    pad_d = (-d) % bd
    if pad_m or pad_d:
        a = jnp.pad(a, ((0, pad_m), (0, pad_d)))
    out = pairwise_sqdist_kernel(a, block_d=bd, interpret=interpret)
    return out[:m, :m]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_accumulate_sqdist(acc, g, reset, scale, *, block_d=None,
                            interpret: bool = True):
    """Fused safeguard update: ``new = [reset ? 0 : acc] + g * scale`` and
    the (m, m) pairwise squared distances of ``new``, in one streamed pass
    (each d-tile of the accumulator goes HBM -> VMEM -> MXU exactly once).

    acc, g: (m, d) f32 — the flat-buffer layout already pads d to the
    lane multiple, so on TPU only the worker rows may need sublane
    padding; the interpreter needs none.  reset: () bool/int.
    scale: () float.

    Returns (new_acc (m, d) f32, sqdist (m, m) f32).
    """
    m, d = acc.shape
    pad_m = 0 if interpret else (-m) % 8
    bd = _pick_block(d + (-d) % _LANE, block_d, interpret)
    pad_d = (-d) % bd                    # pad to a tile multiple
    if pad_m or pad_d:
        acc = jnp.pad(acc, ((0, pad_m), (0, pad_d)))
        g = jnp.pad(g, ((0, pad_m), (0, pad_d)))
    reset1 = jnp.asarray(reset, jnp.int32).reshape((1,))
    scale1 = jnp.asarray(scale, jnp.float32).reshape((1,))
    new, sq = fused_accumulate_sqdist_kernel(
        acc.astype(jnp.float32), g.astype(jnp.float32), reset1, scale1,
        block_d=bd, interpret=interpret)
    if pad_m or pad_d:
        new, sq = new[:m, :d], sq[:m, :m]
    return new, sq
