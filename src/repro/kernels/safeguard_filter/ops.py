"""Jit-able wrapper for the safeguard pairwise-distance kernel: handles
ragged d (zero-pad to a lane multiple — zeros do not change distances) and
worker counts that are not sublane-aligned."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.safeguard_filter.kernel import pairwise_sqdist_kernel


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def pairwise_sqdist(a, *, block_d: int = 512, interpret: bool = True):
    """a: (m, d) any dtype -> (m, m) f32 squared distances."""
    m, d = a.shape
    pad_m = (-m) % 8                     # TPU sublane multiple
    bd = min(block_d, max(128, 128 * ((d + 127) // 128)))
    pad_d = (-d) % bd
    if pad_m or pad_d:
        a = jnp.pad(a, ((0, pad_m), (0, pad_d)))
    out = pairwise_sqdist_kernel(a, block_d=bd, interpret=interpret)
    return out[:m, :m]
