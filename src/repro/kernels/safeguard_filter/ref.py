"""Pure-jnp oracle for the safeguard pairwise-distance kernel."""

import jax.numpy as jnp


def gram(a):
    """(m, d) -> (m, m) float32 Gram matrix."""
    af = a.astype(jnp.float32)
    return af @ af.T


def pairwise_sqdist(a):
    """(m, d) -> (m, m) float32 squared L2 distances, clipped at 0."""
    g = gram(a)
    diag = jnp.diagonal(g)
    return jnp.maximum(diag[:, None] + diag[None, :] - 2.0 * g, 0.0)


def fused_accumulate_sqdist(acc, g, reset, scale):
    """Oracle for the fused safeguard update: windowed accumulate-and-reset
    followed by pairwise distances of the updated accumulators."""
    new = jnp.where(jnp.asarray(reset, bool), jnp.zeros_like(acc),
                    acc).astype(jnp.float32) \
        + g.astype(jnp.float32) * jnp.float32(scale)
    return new, pairwise_sqdist(new)
