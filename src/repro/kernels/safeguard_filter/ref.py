"""Pure-jnp oracle for the safeguard pairwise-distance kernel."""

import jax.numpy as jnp


def gram(a):
    """(m, d) -> (m, m) float32 Gram matrix."""
    af = a.astype(jnp.float32)
    return af @ af.T


def pairwise_sqdist(a):
    """(m, d) -> (m, m) float32 squared L2 distances, clipped at 0."""
    g = gram(a)
    diag = jnp.diagonal(g)
    return jnp.maximum(diag[:, None] + diag[None, :] - 2.0 * g, 0.0)
