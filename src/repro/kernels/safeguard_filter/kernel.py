"""Pallas kernel: blocked Gram / pairwise-distance matrix of per-worker
gradient accumulators.

The safeguard filter needs all pairwise distances between m worker
accumulators of dimension d (d = model size, up to tens of billions).
Distances reduce to the Gram matrix, which is a rank-d update streamed
through VMEM:

    grid over d-tiles; each step loads an (m, bd) tile of the stacked
    accumulator (HBM -> VMEM), issues one (m x bd) @ (bd x m)^T MXU
    matmul, and accumulates into an f32 (m, m) VMEM scratch; the final
    step expands the diagonal to emit squared distances.

m is padded to the sublane multiple by ``ops.py``; ``block_d`` is a
multiple of the 128-wide lane dimension so each tile is MXU-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(a_ref, out_ref, acc_ref, *, nd: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)                 # (m, bd)
    acc_ref[...] += jax.lax.dot_general(
        a, a, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (m, m)

    @pl.when(i == nd - 1)
    def _finish():
        g = acc_ref[...]
        diag = jnp.diagonal(g)
        sq = diag[:, None] + diag[None, :] - 2.0 * g
        out_ref[...] = jnp.maximum(sq, 0.0)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def pairwise_sqdist_kernel(a, *, block_d: int = 512,
                           interpret: bool = True):
    """a: (m, d) with d divisible by block_d.  Returns (m, m) f32."""
    m, d = a.shape
    assert d % block_d == 0, (d, block_d)
    nd = d // block_d
    return pl.pallas_call(
        functools.partial(_gram_kernel, nd=nd),
        grid=(nd,),
        in_specs=[pl.BlockSpec((m, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m, m), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m, m), jnp.float32)],
        interpret=interpret,
    )(a)
