"""Pallas kernels: blocked Gram / pairwise-distance pass over the flat
per-worker accumulator buffer (DESIGN.md §5, §6).

The safeguard filter needs all pairwise distances between m worker
accumulators of dimension d (d = model size, up to tens of billions).
Distances reduce to the Gram matrix, which is a rank-d update streamed
through VMEM:

    grid over d-tiles; each step loads an (m, bd) tile of the flat
    accumulator (HBM -> VMEM), issues one (m x bd) @ (bd x m)^T MXU
    matmul, and accumulates into an f32 (m, m) VMEM scratch; the final
    step expands the diagonal to emit squared distances.

Two entry points:

  * ``pairwise_sqdist_kernel`` — distances of an existing buffer;
  * ``fused_accumulate_sqdist_kernel`` — the safeguard hot path: each
    d-tile additionally applies the windowed accumulate-and-reset update
    ``acc <- [reset ? 0 : acc] + g / n_good`` *in place*
    (``input_output_aliases``) before feeding the MXU, so the O(m d)
    state is streamed exactly once per step.

m is padded to the sublane multiple by ``ops.py`` / the flat layout;
``block_d`` is a multiple of the 128-wide lane dimension so each tile is
MXU-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(a_ref, out_ref, acc_ref, *, nd: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)                 # (m, bd)
    acc_ref[...] += jax.lax.dot_general(
        a, a, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (m, m)

    @pl.when(i == nd - 1)
    def _finish():
        g = acc_ref[...]
        diag = jnp.diagonal(g)
        sq = diag[:, None] + diag[None, :] - 2.0 * g
        out_ref[...] = jnp.maximum(sq, 0.0)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def pairwise_sqdist_kernel(a, *, block_d: int = 512,
                           interpret: bool = True):
    """a: (m, d) with d divisible by block_d.  Returns (m, m) f32."""
    m, d = a.shape
    assert d % block_d == 0, (d, block_d)
    nd = d // block_d
    return pl.pallas_call(
        functools.partial(_gram_kernel, nd=nd),
        grid=(nd,),
        in_specs=[pl.BlockSpec((m, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m, m), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m, m), jnp.float32)],
        interpret=interpret,
    )(a)


def _fused_kernel(reset_ref, scale_ref, acc_ref, g_ref, newacc_ref,
                  out_ref, gram_ref, *, nd: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        gram_ref[...] = jnp.zeros_like(gram_ref)

    # select, NOT multiply-by-(1-reset): a Byzantine inf/NaN in the old
    # accumulator must be zeroed by the window reset (inf * 0 = NaN)
    a = acc_ref[...].astype(jnp.float32)
    a = jnp.where(reset_ref[0] != 0, jnp.zeros_like(a), a)
    new = a + g_ref[...].astype(jnp.float32) * scale_ref[0]
    newacc_ref[...] = new
    gram_ref[...] += jax.lax.dot_general(
        new, new, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == nd - 1)
    def _finish():
        g = gram_ref[...]
        diag = jnp.diagonal(g)
        sq = diag[:, None] + diag[None, :] - 2.0 * g
        out_ref[...] = jnp.maximum(sq, 0.0)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_accumulate_sqdist_kernel(acc, g, reset, scale, *,
                                   block_d: int = 512,
                                   interpret: bool = True):
    """One streamed pass of the safeguard update (DESIGN.md §6).

    acc, g: (m, d) f32 with d divisible by block_d; reset: (1,) int32;
    scale: (1,) f32 (= 1 / n_good).  Returns (new_acc, sqdist) where
    new_acc aliases acc's buffer and sqdist is the (m, m) f32 pairwise
    squared-distance matrix of the UPDATED accumulators.
    """
    m, d = acc.shape
    assert g.shape == (m, d), (acc.shape, g.shape)
    assert d % block_d == 0, (d, block_d)
    nd = d // block_d
    return pl.pallas_call(
        functools.partial(_fused_kernel, nd=nd),
        grid=(nd,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # reset
            pl.BlockSpec(memory_space=pltpu.SMEM),            # scale
            pl.BlockSpec((m, block_d), lambda i: (0, i)),     # acc tile
            pl.BlockSpec((m, block_d), lambda i: (0, i)),     # grad tile
        ],
        out_specs=[
            pl.BlockSpec((m, block_d), lambda i: (0, i)),     # new acc
            pl.BlockSpec((m, m), lambda i: (0, 0)),           # sqdist
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, d), jnp.float32),
            jax.ShapeDtypeStruct((m, m), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((m, m), jnp.float32)],
        input_output_aliases={2: 0},
        interpret=interpret,
    )(reset, scale, acc, g)
