"""Jit-able wrappers: pad the coordinate axis to a lane multiple (padding
columns are reduced too but sliced away — values are irrelevant)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.robust_agg.kernel import sorted_reduce_kernel


def _pad_cols(g, bd):
    d = g.shape[1]
    pad = (-d) % bd
    if pad:
        g = jnp.pad(g, ((0, 0), (0, pad)))
    return g, d


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def coord_median(g, *, block_d: int = 1024, interpret: bool = True):
    """(m, d) -> (d,) f32 coordinate-wise median."""
    bd = min(block_d, max(128, g.shape[1]))
    bd -= bd % 128 or 0
    bd = max(bd, 128)
    gp, d = _pad_cols(g, bd)
    return sorted_reduce_kernel(gp, median=True, block_d=bd,
                                interpret=interpret)[:d]


@functools.partial(jax.jit, static_argnames=("trim", "block_d", "interpret"))
def trimmed_mean(g, *, trim: int, block_d: int = 1024,
                 interpret: bool = True):
    """(m, d) -> (d,) f32 trimmed mean (drop ``trim`` low/high)."""
    if 2 * trim >= g.shape[0]:
        raise ValueError(f"trim {trim} too large for m={g.shape[0]}")
    bd = min(block_d, max(128, g.shape[1]))
    bd -= bd % 128 or 0
    bd = max(bd, 128)
    gp, d = _pad_cols(g, bd)
    return sorted_reduce_kernel(gp, trim=trim, block_d=bd,
                                interpret=interpret)[:d]
