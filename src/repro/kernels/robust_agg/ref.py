"""Pure-jnp oracles for the robust aggregation kernels."""

import jax.numpy as jnp


def coord_median(g):
    """(m, d) -> (d,) per-coordinate median, f32."""
    return jnp.median(g.astype(jnp.float32), axis=0)


def trimmed_mean(g, trim: int):
    """(m, d) -> (d,): drop ``trim`` smallest/largest per coord, mean."""
    m = g.shape[0]
    s = jnp.sort(g.astype(jnp.float32), axis=0)
    return s[trim:m - trim].mean(axis=0)
