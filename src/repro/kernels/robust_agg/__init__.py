from repro.kernels.robust_agg.ops import coord_median, trimmed_mean  # noqa: F401
from repro.kernels.robust_agg import ref                             # noqa: F401
