"""Pallas kernel: coordinate-wise robust statistics over the worker axis.

Historyless baselines (coordinate-wise median [Yin et al. 18], trimmed
mean) reduce m worker gradients coordinate-by-coordinate.  On TPU the
coordinate axis is the 128-lane dimension and the (small, <=64) worker
axis sits on sublanes, so a bitonic-style sort over sublanes vectorizes
across 128 coordinates at once:

    grid over d-tiles: load (m, bd) into VMEM, sort along the worker axis
    with a compare-exchange network (jnp.sort lowers to one), then emit
    the median / trimmed mean of the sorted tile.

One kernel serves both statistics: ``trim`` is a static parameter; the
median is the maximal trim (plus mid-pair averaging for even m).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sorted_reduce_kernel(g_ref, out_ref, *, m: int, trim: int,
                          median: bool):
    g = g_ref[...].astype(jnp.float32)          # (m, bd)
    s = jnp.sort(g, axis=0)
    if median:
        if m % 2:
            out_ref[...] = s[m // 2][None]
        else:
            out_ref[...] = (0.5 * (s[m // 2 - 1] + s[m // 2]))[None]
    else:
        kept = s[trim:m - trim]
        out_ref[...] = jnp.mean(kept, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("trim", "median", "block_d",
                                             "interpret"))
def sorted_reduce_kernel(g, *, trim: int = 0, median: bool = False,
                         block_d: int = 1024, interpret: bool = True):
    """g: (m, d), d divisible by block_d -> (d,) f32."""
    m, d = g.shape
    assert d % block_d == 0, (d, block_d)
    nd = d // block_d
    out = pl.pallas_call(
        functools.partial(_sorted_reduce_kernel, m=m, trim=trim,
                          median=median),
        grid=(nd,),
        in_specs=[pl.BlockSpec((m, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(g)
    return out[0]
