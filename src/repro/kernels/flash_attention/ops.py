"""Jit-able flash-attention wrapper: picks MXU-aligned block sizes and
pads the sequence (padded keys are masked out by causality since padded
queries sit after all real queries and are sliced away)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, window: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B, H, L, D); k, v: (B, K, L, D) -> (B, H, L, D)."""
    B, H, L, D = q.shape
    bq, bk = min(block_q, L), min(block_k, L)
    pad = (-L) % max(bq, bk)
    if pad:
        zq = jnp.zeros((B, H, pad, D), q.dtype)
        zk = jnp.zeros((B, k.shape[1], pad, D), k.dtype)
        q = jnp.concatenate([q, zq], axis=2)
        k = jnp.concatenate([k, zk], axis=2)
        v = jnp.concatenate([v, zk], axis=2)
    out = flash_attention_kernel(q, k, v, block_q=bq, block_k=bk,
                                 window=window, interpret=interpret)
    return out[:, :, :L]
