"""Pallas kernel: blocked causal flash attention (GQA + sliding window).

Grid: ``(B, H, n_q, n_kv)`` — the kv axis is innermost and sequential on
TPU, so VMEM scratch (running max ``m``, normalizer ``l`` and the f32
output accumulator) carries across kv steps and is re-initialized at
``ik == 0``.  Block shapes:

    q:   (1, 1, bq, D)   index (b, h, iq, 0)
    k/v: (1, 1, bk, D)   index (b, h // group, ik, 0)   <- GQA head map
    out: (1, 1, bq, D)   index (b, h, iq, 0)            (ignores ik)

Causality and the sliding window are applied as in-block masks against the
absolute positions; blocks entirely above the diagonal or entirely outside
the window skip their matmuls via ``pl.when`` (the dominant saving for the
32k/500k decode shapes).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, bq: int, bk: int, n_kv: int, window: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk
    # block-level reachability: any (qpos >= kpos) and window overlap
    reachable = k_start <= q_start + bq - 1
    if window > 0:
        reachable &= (k_start + bk - 1) > (q_start - window)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG)

        m_prev = m_ref[...]                            # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "window",
                                             "interpret"))
def flash_attention_kernel(q, k, v, *, block_q: int = 128,
                           block_k: int = 128, window: int = 0,
                           interpret: bool = True):
    """q: (B, H, L, D); k, v: (B, K, L, D); L divisible by both blocks."""
    B, H, L, D = q.shape
    K = k.shape[1]
    assert L % block_q == 0 and L % block_k == 0, (L, block_q, block_k)
    group = H // K
    n_q, n_kv = L // block_q, L // block_k
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_flash_kernel, scale=scale, bq=block_q,
                               bk=block_k, n_kv=n_kv, window=window)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, L, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
