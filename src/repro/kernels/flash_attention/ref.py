"""Pure-jnp oracle for causal (sliding-window, GQA) attention."""

import math

import jax
import jax.numpy as jnp


def attention(q, k, v, *, window: int = 0):
    """q: (B, H, L, D); k, v: (B, K, L, D); causal; optional window.
    Returns (B, H, L, D) in q's dtype; softmax in f32."""
    B, H, L, D = q.shape
    K = k.shape[1]
    qg = q.reshape(B, K, H // K, L, D)
    s = jnp.einsum("bkgld,bksd->bkgls", qg, k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    qpos = jnp.arange(L)[:, None]
    kpos = jnp.arange(L)[None, :]
    mask = kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgls,bksd->bkgld", p, v,
                   preferred_element_type=jnp.float32).astype(q.dtype)
    return o.reshape(B, H, L, D)
