"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §5):

  * ``safeguard_filter`` — the master's O(m^2 d) pairwise-distance pass
    over per-worker accumulators, d-tiled through VMEM with MXU rank-k
    Gram updates;
  * ``robust_agg``       — coordinate-wise median / trimmed-mean baselines
    (VPU sorting networks over the worker axis, d-tiled);
  * ``flash_attention``  — causal (+sliding-window, +GQA) blocked
    online-softmax attention shared by all transformer archs.

Each package ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit-able wrapper with padding/dispatch) and ``ref.py`` (pure-jnp oracle).
Kernels are validated on CPU with ``interpret=True``; TPU is the target.
"""
