"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §5):

  * ``safeguard_filter`` — the master's O(m^2 d) pairwise-distance pass
    over the flat ``(m, d_pad)`` accumulator buffer (DESIGN.md §6),
    d-tiled through VMEM with MXU rank-k Gram updates; ships both the
    plain Gram/distance kernel and the fully fused variant that applies
    the windowed accumulate-and-reset in place (``input_output_aliases``)
    while streaming each tile exactly once;
  * ``robust_agg``       — coordinate-wise median / trimmed-mean baselines
    (VPU sorting networks over the worker axis, d-tiled);
  * ``flash_attention``  — causal (+sliding-window, +GQA) blocked
    online-softmax attention shared by all transformer archs.

Each package ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit-able wrapper with padding/tile choice/dispatch) and ``ref.py``
(pure-jnp oracle).  Kernels are validated on CPU with ``interpret=True``
against the oracle; TPU is the compiled target.
"""
