"""Minimal dependency-free pytree checkpointing.

Leaves are flattened to ``path -> array`` and stored in a single ``.npz``
per step alongside a JSON sidecar carrying the treedef (as path list) and
user metadata.  Supports any nested dict/list/tuple pytree of jnp/np
arrays — params, optimizer state, and the safeguard accumulators alike.
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_part(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_part(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return f"#{p.idx}"
    if isinstance(p, jax.tree_util.GetAttrKey):
        return p.name
    return str(p)


def _insert(root, parts, value):
    head = parts[0]
    is_idx = head.startswith("#")
    key = int(head[1:]) if is_idx else head
    if len(parts) == 1:
        if is_idx:
            while len(root) <= key:
                root.append(None)
            root[key] = value
        else:
            root[key] = value
        return
    nxt_is_idx = parts[1].startswith("#")
    if is_idx:
        while len(root) <= key:
            root.append(None)
        if root[key] is None:
            root[key] = [] if nxt_is_idx else {}
        _insert(root[key], parts[1:], value)
    else:
        if key not in root:
            root[key] = [] if nxt_is_idx else {}
        _insert(root[key], parts[1:], value)


def save(ckpt_dir: str, step: int, tree, metadata: Optional[dict] = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}")
    np.savez(path + ".npz", **flat)
    meta = {"step": step, "keys": sorted(flat),
            "metadata": metadata or {}}
    with open(path + ".json", "w") as f:
        json.dump(meta, f)
    return path + ".npz"


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1))
             for f in os.listdir(ckpt_dir)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None
            ) -> Tuple[Any, dict]:
    """Returns (tree, metadata).  Lists/dicts are reconstructed from the
    stored paths; arrays come back as numpy (cast with tree_map if you
    need device arrays)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}")
    data = np.load(path + ".npz")
    with open(path + ".json") as f:
        meta = json.load(f)
    root: Dict[str, Any] = {}
    for key in data.files:
        _insert(root, key.split("/"), data[key])
    return root, meta
