"""Sharding rules: parameter / gradient / cache PartitionSpecs.

Strategy (DESIGN.md §3): FSDP x TP —

  * every parameter leaf shards its largest eligible dim over ``model``
    (tensor parallel) and the next eligible dim over the data axes (fully
    sharded data parallel), leading layer-stack axes excluded;
  * MoE expert tensors override the heuristic: the expert dim goes to
    ``model`` (expert parallelism), the feature dim to data;
  * stacked per-worker gradients put the worker axis on the data axes and
    keep only the ``model`` assignments of the underlying parameter — the
    worker axis *is* the data axis;
  * the flat safeguard accumulators (``(m_pad, d_pad)`` buffers, DESIGN.md
    §6) shard their worker-row axis over the data axes — each data shard
    owns its own workers' rows, so the windowed accumulate is collective-
    free and only the ``(m, m)`` distance matrix is combined across shards
    (:func:`flat_acc_pspec`); the padded feature axis goes to ``model``
    when divisible;
  * decode caches shard batch over data and the largest remaining eligible
    dim (kv-heads, latent rank, or sequence) over model.

A dim is eligible for an axis only if its size divides evenly; otherwise
the next-largest dim is tried, falling back to replication.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import mesh as mesh_lib


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _has_stack_axis(pstr: str) -> bool:
    return ("blocks" in pstr and "pre_blocks" not in pstr
            and "tail_blocks" not in pstr)


def _assign(shape, skip: int, model_n: int, data_axes: Tuple[str, ...],
            data_n: int):
    """Greedy largest-divisible-dim assignment -> list of axis names."""
    spec = [None] * len(shape)
    order = sorted(range(skip, len(shape)), key=lambda i: -shape[i])
    # model axis first
    for i in order:
        if shape[i] % model_n == 0 and shape[i] >= model_n:
            spec[i] = "model"
            break
    for i in order:
        if spec[i] is None and shape[i] % data_n == 0 and shape[i] >= data_n:
            spec[i] = data_axes if len(data_axes) > 1 else data_axes[0]
            break
    return spec


# Megatron-style orientation rules: column-parallel weights shard their
# OUTPUT (last) dim over `model` (no collective in the forward matmul);
# row-parallel weights shard their INPUT (first non-stack) dim and incur
# one all-reduce/reduce-scatter after the matmul.  Without these, square
# weights (e.g. deepseek-coder's 7168x7168 wq) can end up sharded on the
# contracting dim, paying a full-activation psum per projection
# (EXPERIMENTS.md §Perf).
_COLUMN_PARALLEL = ("wq", "wk", "wv", "w_gate", "w_up", "w_uq", "w_uk",
                    "w_uv", "w_kr", "w_dq", "w_dkv", "in_proj", "w_x",
                    "w_y", "w_i", "w_r", "lm_head")
_ROW_PARALLEL = ("wo", "w_down", "out_proj", "w_o")


def param_pspec(path, leaf, mesh) -> P:
    pstr = _path_str(path)
    shape = leaf.shape
    model_n = mesh_lib.model_size(mesh)
    data_axes = mesh_lib.worker_axes(mesh)
    data_n = mesh_lib.data_size(mesh)
    skip = 1 if _has_stack_axis(pstr) and len(shape) > 1 else 0

    if len(shape) - skip <= 1:
        return P(*([None] * len(shape)))

    leaf_name = pstr.rsplit("/", 1)[-1]
    is_moe_expert = ("/moe/" in f"/{pstr}/" and len(shape) - skip == 3)
    first, last = skip, len(shape) - 1
    oriented = (last if leaf_name in _COLUMN_PARALLEL else first)
    if not is_moe_expert and (leaf_name in _COLUMN_PARALLEL
                              or leaf_name in _ROW_PARALLEL) \
            and shape[oriented] >= 1024:
        # orientation override only for substantial dims — tiny outputs
        # (MQA/GQA kv projections) do better under the size heuristic
        order = ([last, first] if leaf_name in _COLUMN_PARALLEL
                 else [first, last])
        spec = [None] * len(shape)
        for i in order:
            if shape[i] % model_n == 0 and shape[i] >= model_n:
                spec[i] = "model"
                break
        for i in (first, last):
            if spec[i] is None and shape[i] % data_n == 0 \
                    and shape[i] >= data_n:
                spec[i] = data_axes if len(data_axes) > 1 else data_axes[0]
                break
        return P(*spec)

    # MoE experts: (stack, E, d, f) / (stack, E, f, d) — expert parallel
    if "/moe/" in f"/{pstr}/" and pstr.rsplit("/", 1)[-1] in (
            "w_gate", "w_up", "w_down") and len(shape) - skip == 3:
        E, a, b = shape[skip], shape[skip + 1], shape[skip + 2]
        spec = [None] * len(shape)
        if E % model_n == 0:
            spec[skip] = "model"
            if a % data_n == 0:
                spec[skip + 1] = (data_axes if len(data_axes) > 1
                                  else data_axes[0])
        else:
            return P(*_assign(shape, skip, model_n, data_axes, data_n))
        return P(*spec)

    if pstr.rsplit("/", 1)[-1] == "router":
        # replicate the (small) expert dim; shard d over data
        spec = [None] * len(shape)
        if shape[skip] % data_n == 0:
            spec[skip] = data_axes if len(data_axes) > 1 else data_axes[0]
        return P(*spec)

    return P(*_assign(shape, skip, model_n, data_axes, data_n))


def params_pspecs(abstract_params, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(path, leaf, mesh), abstract_params)


def stacked_grad_pspec(param_spec: P, mesh) -> P:
    """Worker-stacked version of a parameter spec: worker axis on the data
    axes, keep only the 'model' assignment of the tail."""
    data_axes = mesh_lib.worker_axes(mesh)
    worker = data_axes if len(data_axes) > 1 else data_axes[0]
    tail = [s if s == "model" else None for s in param_spec]
    return P(worker, *tail)


def stacked_grads_pspecs(param_specs, mesh):
    return jax.tree.map(
        lambda spec: stacked_grad_pspec(spec, mesh), param_specs,
        is_leaf=lambda x: isinstance(x, P))


def flat_acc_pspec(mesh, d_padded: int) -> P:
    """Partition spec of a flat safeguard accumulator ``(m_pad, d_pad)``:
    worker rows over the data axes (each shard owns a worker-row slice, so
    the fused accumulate-and-reset is local), the padded feature axis over
    ``model`` when divisible.  Under this spec the only cross-shard traffic
    of the safeguard pass is the tiny ``(m, m)`` Gram combine."""
    data_axes = mesh_lib.worker_axes(mesh)
    worker = data_axes if len(data_axes) > 1 else data_axes[0]
    col = "model" if d_padded % mesh_lib.model_size(mesh) == 0 else None
    return P(worker, col)


def cache_pspec(path, leaf, mesh, batch: int) -> P:
    pstr = _path_str(path)
    shape = leaf.shape
    if leaf.ndim == 0 or pstr.endswith("pos"):
        return P()
    model_n = mesh_lib.model_size(mesh)
    data_axes = mesh_lib.worker_axes(mesh)
    data_n = mesh_lib.data_size(mesh)
    skip = 1 if _has_stack_axis(pstr) else 0
    spec = [None] * len(shape)
    # batch axis -> data
    if len(shape) > skip and shape[skip] == batch and batch % data_n == 0:
        spec[skip] = data_axes if len(data_axes) > 1 else data_axes[0]
    # largest remaining divisible dim -> model
    order = sorted(range(skip + 1, len(shape)), key=lambda i: -shape[i])
    for i in order:
        if spec[i] is None and shape[i] % model_n == 0 \
                and shape[i] >= model_n:
            spec[i] = "model"
            break
    return P(*spec)


def cache_pspecs(abstract_cache, mesh, batch: int):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_pspec(path, leaf, mesh, batch),
        abstract_cache)


def batch_pspec(path, leaf, mesh, m: Optional[int]) -> P:
    """Training batches are worker-stacked (m, b, ...); serving batches are
    (B, ...).  Embedding inputs additionally shard d over model."""
    data_axes = mesh_lib.worker_axes(mesh)
    worker = data_axes if len(data_axes) > 1 else data_axes[0]
    pstr = _path_str(path)
    spec = [None] * leaf.ndim
    data_n = mesh_lib.data_size(mesh)
    if leaf.ndim and leaf.shape[0] % data_n == 0 and leaf.shape[0] > 0:
        spec[0] = worker
    if pstr.endswith("embeds"):
        model_n = mesh_lib.model_size(mesh)
        if leaf.shape[-1] % model_n == 0:
            spec[-1] = "model"
    return P(*spec)


def batch_pspecs(abstract_batch, mesh, m: Optional[int] = None):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: batch_pspec(path, leaf, mesh, m), abstract_batch)


def with_shardings(abstract_tree, spec_tree, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    return jax.tree.map(
        lambda s, spec: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, spec)),
        abstract_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
