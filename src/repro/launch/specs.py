"""Step builders + abstract input specs for the dry run.

For each (architecture, input shape) pair this module constructs the jit
target and its fully-sharded ShapeDtypeStruct arguments — no device
allocation ever happens (``jax.eval_shape`` end to end):

  * ``train_4k``            -> the full SafeguardSGD training step (per
    worker grads -> filter -> SGD), m = pod*data workers;
  * ``prefill_32k``         -> full-sequence prefill returning the decode
    cache;
  * ``decode_32k/long_500k`` -> one-token ``serve_step`` against a
    preallocated cache.

``variant`` selects the aggregation implementation for §Perf:
  "exact"    — flat-buffer O(m*d) accumulators (f32, DESIGN.md §6), rows
               sharded over the data axes (XLA backend — the Pallas kernel
               is a per-device program and cannot be partitioned);
  "exact16"  — flat accumulators in bf16;
  "stacked"  — paper-faithful stacked-pytree accumulators (the reference
               representation; keeps per-leaf model-axis sharding);
  "sketch"   — CountSketch safeguard state (beyond paper);
  "mean"     — no safeguard (plain data-parallel SGD; the cost floor).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig, TrainConfig
from repro.core import aggregators as agg_lib
from repro.core import defenses as dfn_lib
from repro.core import safeguard as sg
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as sh
from repro.models import layers as layers_lib
from repro.models import transformer as T
from repro.optim import make_optimizer
from repro.train import trainer as tr


def _replicated(tree, mesh):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, P(*([None] *
                                                               len(s.shape))))),
        tree, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def make_sg_cfg(m: int, variant: str = "exact") -> Optional[sg.SafeguardConfig]:
    if variant == "mean":
        return None
    kwargs: Dict[str, Any] = dict(m=m, T0=100, T1=600, backend="xla")
    if variant == "exact16":
        kwargs["acc_dtype"] = jnp.bfloat16
    if variant == "stacked":
        kwargs["engine"] = "stacked"
    if variant == "sketch":
        kwargs.update(use_sketch=True, sketch_k=2048, sketch_reps=4)
    return sg.SafeguardConfig(**kwargs)


def build_train(cfg: ModelConfig, shape: InputShape, mesh, *,
                variant: str = "exact"):
    """Returns (step_fn, arg_structs tuple) for jit(...).lower(*structs)."""
    m = mesh_lib.n_workers(mesh)
    assert shape.global_batch % m == 0
    per = shape.global_batch // m
    Lseq = shape.seq_len

    sg_cfg = make_sg_cfg(m, variant)
    opt = make_optimizer(TrainConfig(lr=0.01, optimizer="sgd"))
    loss = functools.partial(_loss, cfg)
    waxes = mesh_lib.worker_axes(mesh)
    spmd = waxes if len(waxes) > 1 else waxes[0]
    if sg_cfg is not None:
        defense = dfn_lib.make_safeguard_defense(sg_cfg)
    else:
        defense = dfn_lib.from_aggregator(
            agg_lib.Aggregator("mean", agg_lib.mean))
    acc_sharding = None
    if defense.flat_state:
        # flat (m, d_pad) defense state: worker rows on the data axes,
        # feature columns on model (DESIGN.md §3/§6) — one rule for every
        # flat-buffer defense, not a safeguard special case
        layout = sg.make_layout(T.init_abstract(cfg))
        acc_sharding = NamedSharding(
            mesh, sh.flat_acc_pspec(mesh, layout.d_padded))
    step = tr.make_train_step(loss, opt, byz_mask=jnp.zeros((m,), bool),
                              defense=defense, spmd_axis_name=spmd,
                              acc_sharding=acc_sharding,
                              # the zeta trace layer (DESIGN.md §13) is
                              # campaign telemetry; keep the at-scale hot
                              # path free of its two O(m d) passes
                              trace_zeta=False, jit=False)

    # ---- abstract state with shardings --------------------------------
    params_a = T.init_abstract(cfg)
    pspecs = sh.params_pspecs(params_a, mesh)
    params_s = sh.with_shardings(params_a, pspecs, mesh)

    opt_a = jax.eval_shape(opt.init, params_a)
    opt_specs = jax.tree_util.tree_map_with_path(
        lambda path, leaf: sh.param_pspec(path, leaf, mesh), opt_a)
    opt_s = sh.with_shardings(opt_a, opt_specs, mesh)

    if sg_cfg is not None:
        sg_a = jax.eval_shape(
            functools.partial(sg.init_state, sg_cfg), params_a)
        gspecs = sh.stacked_grads_pspecs(pspecs, mesh)
        sg_s = _sg_with_shardings(sg_a, sg_cfg, gspecs, mesh)
    else:
        sg_s = None

    rng_a = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    state_s = tr.TrainState(
        params=params_s, opt_state=opt_s, defense_state=sg_s,
        attack_state=None,
        step=jax.ShapeDtypeStruct((), jnp.int32,
                                  sharding=NamedSharding(mesh, P())),
        rng=jax.ShapeDtypeStruct(rng_a.shape, rng_a.dtype,
                                 sharding=NamedSharding(mesh, P())),
    )

    batch_a = _abstract_batch(cfg, m, per, Lseq)
    batch_specs = sh.batch_pspecs(batch_a, mesh, m)
    batch_s = sh.with_shardings(batch_a, batch_specs, mesh)
    return step, (state_s, batch_s)


def _sg_with_shardings(sg_a: sg.SafeguardState, sg_cfg, gspecs, mesh):
    def acc(tree):
        if tree is None:
            return None
        if isinstance(tree, jax.ShapeDtypeStruct):
            # flat accumulator (m_pad, d_pad): worker rows on the data
            # axes, feature columns on model (DESIGN.md §3/§6); sketch
            # matrix (m, rk): worker rows on the data axes.
            if sg_a.layout is not None:
                spec = sh.flat_acc_pspec(mesh, sg_a.layout.d_padded)
            else:
                waxes = sh.mesh_lib.worker_axes(mesh)
                spec = P(waxes if len(waxes) > 1 else waxes[0], None)
            return jax.ShapeDtypeStruct(
                tree.shape, tree.dtype, sharding=NamedSharding(mesh, spec))
        return sh.with_shardings(tree, gspecs, mesh)

    rep = lambda s: jax.ShapeDtypeStruct(
        s.shape, s.dtype, sharding=NamedSharding(mesh, P(*([None] *
                                                           len(s.shape)))))
    return sg.SafeguardState(
        good=rep(sg_a.good), step=rep(sg_a.step),
        A=acc(sg_a.A), B=acc(sg_a.B), evicted_at=rep(sg_a.evicted_at),
        layout=sg_a.layout)


def _loss(cfg, params, batch):
    return T.loss_fn(params, cfg, batch)


def _abstract_batch(cfg: ModelConfig, m: int, per: int, Lseq: int):
    if cfg.embed_stub:
        return {
            "embeds": jax.ShapeDtypeStruct((m, per, Lseq, cfg.d_model),
                                           cfg.dtype),
            "labels": jax.ShapeDtypeStruct((m, per, Lseq), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((m, per, Lseq), jnp.int32)}


# --------------------------------------------------------------------------
# Serving
# --------------------------------------------------------------------------

def build_prefill(cfg: ModelConfig, shape: InputShape, mesh):
    B, Lseq = shape.global_batch, shape.seq_len

    def prefill_step(params, inputs):
        return T.prefill(params, cfg, inputs, max_seq=Lseq)

    params_a = T.init_abstract(cfg)
    pspecs = sh.params_pspecs(params_a, mesh)
    params_s = sh.with_shardings(params_a, pspecs, mesh)

    if cfg.embed_stub:
        inp_a = jax.ShapeDtypeStruct((B, Lseq, cfg.d_model), cfg.dtype)
    else:
        inp_a = jax.ShapeDtypeStruct((B, Lseq), jnp.int32)
    inp_spec = sh.batch_pspecs({"embeds" if cfg.embed_stub else "tokens":
                                inp_a}, mesh)
    inp_s = sh.with_shardings({"x": inp_a},
                              {"x": list(inp_spec.values())[0]}, mesh)["x"]
    return prefill_step, (params_s, inp_s)


def build_decode(cfg: ModelConfig, shape: InputShape, mesh):
    B, Lseq = shape.global_batch, shape.seq_len

    def serve_step(params, token, cache):
        return T.decode_step(params, cfg, token, cache)

    params_a = T.init_abstract(cfg)
    pspecs = sh.params_pspecs(params_a, mesh)
    params_s = sh.with_shardings(params_a, pspecs, mesh)

    cache_a = jax.eval_shape(lambda: T.init_cache(cfg, B, Lseq))
    cache_specs = sh.cache_pspecs(cache_a, mesh, B)
    cache_s = sh.with_shardings(cache_a, cache_specs, mesh)

    data_n = mesh_lib.data_size(mesh)
    waxes = mesh_lib.worker_axes(mesh)
    bspec = (waxes if len(waxes) > 1 else waxes[0]) \
        if B % data_n == 0 else None
    if cfg.embed_stub:
        tok_s = jax.ShapeDtypeStruct(
            (B, 1, cfg.d_model), cfg.dtype,
            sharding=NamedSharding(mesh, P(bspec, None, None)))
    else:
        tok_s = jax.ShapeDtypeStruct(
            (B, 1), jnp.int32, sharding=NamedSharding(mesh, P(bspec, None)))
    return serve_step, (params_s, tok_s, cache_s)


def build(cfg: ModelConfig, shape: InputShape, mesh, *,
          variant: str = "exact"):
    # Megatron-style activation constraints for the at-scale build.  The
    # residual anchor (model-axis replication of the stream) is required
    # for the vmapped per-worker TRAIN path; serving paths run leaner
    # without it — XLA keeps per-token ops sequence-sharded and gathers
    # only K/V (EXPERIMENTS.md §Perf).
    layers_lib.enable_activation_sharding(
        True, model_n=mesh_lib.model_size(mesh),
        anchor_residual=(shape.kind == "train"))
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, variant=variant)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh)
    return build_decode(cfg, shape, mesh)
