"""Training driver.

Runs a real (CPU-feasible) Byzantine training experiment on the reduced
configs: pick an architecture, an attack, a defense, and go.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 200 --attack sign_flip --defense safeguard \
        --workers 10 --byz 4

For the at-scale (256/512-chip) lowering of the same step, use
``repro.launch.dryrun`` — this driver is the runnable end-to-end path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.configs.base import TrainConfig
from repro.core import attacks as atk_lib
from repro.core import defenses as dfn_lib
from repro.data import pipeline as data_lib
from repro.models import transformer as T
from repro.optim import make_optimizer
from repro.train import Trainer, init_train_state, make_train_step
from repro import checkpoint as ckpt_lib


def build_defense(name: str, m: int, n_byz: int, args) -> dfn_lib.Defense:
    """Any defense of the protocol registry (DESIGN.md §12);
    ``safeguard`` is an alias for ``safeguard_double``."""
    if name == "safeguard":
        name = "safeguard_double"
    reg = dfn_lib.make_registry(m, n_byz, T0=args.t0, T1=args.t1,
                                threshold_floor=args.floor,
                                reset_period=args.reset_period,
                                use_sketch=args.sketch)
    if name not in reg:
        raise SystemExit(f"unknown defense {name}; "
                         f"choose safeguard|{sorted(reg)}")
    return reg[name]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--full", action="store_true",
                    help="full config (default: reduced smoke config)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=80)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--workers", type=int, default=10)
    ap.add_argument("--byz", type=int, default=4)
    ap.add_argument("--attack", default="sign_flip",
                    choices=sorted(atk_lib.make_registry()))
    ap.add_argument("--defense", default="safeguard")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adam"])
    ap.add_argument("--t0", type=int, default=50)
    ap.add_argument("--t1", type=int, default=200)
    ap.add_argument("--floor", type=float, default=1.0)
    ap.add_argument("--reset-period", type=int, default=0)
    ap.add_argument("--hetero-alpha", type=float, default=0.0,
                    help="Dirichlet worker heterogeneity on the token "
                         "stream (0 = IID, DESIGN.md §13); LM archs only")
    ap.add_argument("--sketch", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out", default=None, help="write history JSON here")
    args = ap.parse_args()

    cfg = C.get(args.arch) if args.full else C.get_smoke(args.arch)
    m, n_byz = args.workers, args.byz
    if args.batch % m:
        raise SystemExit("--batch must be divisible by --workers")
    byz_mask = jnp.arange(m) < n_byz

    attacks = atk_lib.make_registry()
    attack = attacks[args.attack]
    defense = build_defense(args.defense, m, n_byz, args)

    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = make_optimizer(TrainConfig(lr=args.lr, momentum=args.momentum,
                                     optimizer=args.optimizer))
    loss = lambda p, b: T.loss_fn(p, cfg, b)
    state = init_train_state(params, opt, defense=defense, attack=attack,
                             seed=args.seed)
    step = make_train_step(loss, opt, byz_mask=byz_mask, defense=defense,
                           attack=attack)

    flip = byz_mask if attack.data_attack else None
    if cfg.embed_stub:
        if args.hetero_alpha > 0:
            raise SystemExit("--hetero-alpha models token streams; "
                             "stub-frontend archs have no token unigram "
                             "to skew")
        it = data_lib.stub_batches(cfg.d_model, cfg.vocab_size, args.batch,
                                   args.seq, seed=args.seed, m=m,
                                   flip_mask=flip)
    else:
        it = data_lib.lm_batches(cfg.vocab_size, args.batch, args.seq,
                                 seed=args.seed, m=m, flip_mask=flip,
                                 hetero_alpha=args.hetero_alpha)
    held = None
    if defense.needs_held_batch:
        if cfg.embed_stub:
            held = data_lib.stub_batches(cfg.d_model, cfg.vocab_size,
                                         8, args.seq, seed=args.seed + 1)
        else:
            held = data_lib.lm_batches(cfg.vocab_size, 8, args.seq,
                                       seed=args.seed + 1)

    name = f"{cfg.name}/{args.attack}/{args.defense}"
    trainer = Trainer(state, step, it, held_iter=held,
                      log_every=args.log_every, name=name)
    hist = trainer.run(args.steps)

    if args.ckpt_dir:
        ckpt_lib.save(args.ckpt_dir, int(trainer.state.step),
                      {"params": trainer.state.params},
                      metadata={"arch": cfg.name, "attack": args.attack,
                                "defense": args.defense})
        print(f"checkpoint written to {args.ckpt_dir}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"config": vars(args), "history": hist}, f, indent=1)
        print(f"history written to {args.out}")


if __name__ == "__main__":
    main()
