import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (architecture x input shape)
on the production meshes and extract the roofline raw terms.

MUST be run as its own process (the two lines above execute before any
other import so the host platform exposes 512 placeholder devices).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k [--multi-pod] [--variant exact] [--out DIR]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Artifacts: one JSON per (arch, shape, mesh, variant) under
``experiments/dryrun/`` with per-device HLO FLOPs / bytes, memory stats,
and per-collective byte counts parsed from the compiled HLO.
"""

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro import configs as C                    # noqa: E402
from repro.configs.base import INPUT_SHAPES       # noqa: E402
from repro.launch import mesh as mesh_lib         # noqa: E402
from repro.launch import specs as specs_lib       # noqa: E402

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Per-collective-type *output* bytes summed over ops (per device)."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for coll in _COLLECTIVES:
            # "%name = <shape> all-reduce(...)" / fusion-wrapped starts
            if re.search(rf"= [^=]*\b{coll}(-start|-done)?\(", stripped):
                lhs = stripped.split("=", 1)[0] + "=" + \
                    stripped.split("=", 1)[1].split(f"{coll}", 1)[0]
                if coll + "-done" in stripped:
                    continue          # avoid double counting start/done
                out[coll] += _shape_bytes(lhs)
                counts[coll] += 1
                break
    return out, counts


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            variant: str = "exact", out_dir: str = "experiments/dryrun",
            save: bool = True, verbose: bool = True):
    cfg = C.get(arch)
    shape = INPUT_SHAPES[shape_name]

    if shape_name == "long_500k" and not cfg.sub_quadratic:
        rec = {"arch": arch, "shape": shape_name, "variant": variant,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "status": "skipped",
               "reason": "pure full-attention arch; 524k dense decode "
                         "cache excluded by design (DESIGN.md §7)"}
        if save:
            _write(rec, out_dir)
        if verbose:
            print(f"[dryrun] {arch} x {shape_name}: SKIP (full attention)")
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    t0 = time.time()
    step, args = specs_lib.build(cfg, shape, mesh, variant=variant)
    with mesh:
        lowered = jax.jit(step).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):      # jax <= 0.4.x: [dict]
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    hlo_txt = compiled.as_text()
    colls, coll_counts = collective_bytes(hlo_txt)

    # loop-aware accounting (XLA cost_analysis counts while bodies once —
    # scanned-layer models undercount by n_layers; see hlo_analysis.py)
    from repro.launch.hlo_analysis import analyze_hlo
    try:
        loop_aware = analyze_hlo(hlo_txt)
    except Exception as e:                                # noqa: BLE001
        loop_aware = {"error": repr(e)}

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "variant": variant,
        "status": "ok",
        "n_devices": int(mesh.devices.size),
        # raw XLA numbers (loop bodies counted once)
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        # loop-aware per-device numbers (use these for the roofline)
        "flops_per_device_loop_aware": loop_aware.get("flops"),
        "hbm_bytes_per_device_loop_aware": loop_aware.get("hbm_bytes"),
        "collective_bytes_loop_aware": loop_aware.get("collective_bytes"),
        "collective_counts_loop_aware": loop_aware.get("collective_counts"),
        "collective_bytes": colls,
        "collective_counts": coll_counts,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    if save:
        _write(rec, out_dir)
    if verbose:
        live = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes - mem.alias_size_in_bytes)
        la = rec.get("flops_per_device_loop_aware") or 0.0
        lac = rec.get("collective_bytes_loop_aware") or {}
        print(f"[dryrun] {arch} x {shape_name} ({mesh_name}, {variant}): "
              f"OK  flops/dev={la:.3e}  "
              f"live_mem/dev={live/2**30:.2f}GiB  "
              f"coll={ {k: f'{v/2**30:.1f}G' for k, v in lac.items() if v} }  "
              f"compile={t_compile:.1f}s")
    return rec


def _write(rec, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}__{rec['variant']}"
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="exact",
                    choices=["exact", "exact16", "stacked", "sketch",
                             "mean"])
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch, shape)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        archs = C.ARCH_IDS + C.EXTRA_IDS
        shapes = list(INPUT_SHAPES)
    else:
        if not args.arch or not args.shape:
            ap.error("need --arch and --shape (or --all)")
        archs, shapes = [args.arch], [args.shape]

    failures = []
    for arch in archs:
        for shape in shapes:
            try:
                run_one(arch, shape, multi_pod=args.multi_pod,
                        variant=args.variant, out_dir=args.out)
            except Exception as e:                     # noqa: BLE001
                traceback.print_exc()
                failures.append((arch, shape, repr(e)))
                print(f"[dryrun] {arch} x {shape}: FAIL {e}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry runs OK")


if __name__ == "__main__":
    main()
