"""Loop-aware cost analysis of compiled (SPMD-partitioned) HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body* once,
which undercounts scanned-layer models by a factor of ``n_layers`` (and
blocked-flash inner scans by their trip counts).  This module re-derives
the roofline raw terms from the HLO text with loop multipliers:

  * parse the module into computations and ops (shapes, opcodes, operands,
    called computations);
  * recover each while's trip count from its condition computation (the
    largest integer constant compared against the induction variable);
  * propagate multipliers from ENTRY through while/call/fusion/
    conditional edges;
  * FLOPs: ``2 * numel(output) * prod(contracting dims)`` for every dot
    (plus the same for convolutions via their window), times multiplier;
  * HBM bytes: operands + outputs of every *top-level* op in executed
    computations (fusion internals excluded — they stay in registers /
    VMEM), times multiplier;
  * collective bytes: output sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute ops, times
    multiplier.

Shapes in partitioned HLO are per-device, so every result is per-chip —
exactly what the roofline terms need.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_info(text: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Total bytes and (dtype, dims) list for a shape string (handles
    tuples)."""
    total, shapes = 0, []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        dl = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in dl:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, dl))
    return total, shapes


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    out_bytes: int
    out_dims: List[int]
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    shapes: Dict[str, Tuple[int, List[int]]]     # symbol -> (bytes, dims)


_COMP_HEADER = re.compile(
    r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\((.*)\)\s*->")
# "%name = TYPE opcode(..." — TYPE may be a (possibly huge) tuple with
# /*index=k*/ comments; the opcode is the first lowercase word followed by
# an open paren after the '='.
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+?)\s([a-z][\w\-]*)\((.*)$")
_OPERAND = re.compile(r"%[\w.\-]+")
_CALLED = re.compile(
    r"(?:condition|body|calls|to|branch_computations)=\{?(%[\w.\-]+"
    r"(?:,\s*%[\w.\-]+)*)\}?")


def parse_module(txt: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        header = _COMP_HEADER.match(line)
        if header and line.endswith("{"):
            cur = Computation(header.group(1), [], {})
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            # parameter shapes from the signature
            for pname, pshape in re.findall(
                    r"([\w.\-]+):\s*([a-z0-9]+\[[0-9,]*\])",
                    header.group(2)):
                b, shp = _shape_info(pshape)
                dims = shp[0][1] if shp else []
                cur.shapes["%" + pname] = (b, dims)
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, shape_txt, opcode, rest = m.groups()
        out_bytes, shapes = _shape_info(shape_txt)
        out_dims = shapes[0][1] if shapes else []
        # operands: %refs inside the parens, before attribute section
        paren = rest.split("),", 1)[0]
        operands = _OPERAND.findall(paren)
        op = Op(name, opcode, out_bytes, out_dims, operands, line)
        cur.ops.append(op)
        cur.shapes[name] = (out_bytes, out_dims)
    return comps, entry


def _trip_count(cond: Computation, comps: Dict[str, "Computation"],
                _depth: int = 0) -> int:
    """Largest (sane) integer constant reachable from the condition
    computation — the loop bound for scan-style counted loops.  Constants
    may live inside fusions called by the condition, so recurse one hop.
    Falls back to 1."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\-?\d+)\)", op.line)
            if m and 0 < int(m.group(1)) < 10 ** 6:
                best = max(best, int(m.group(1)))
        elif _depth < 2:
            for cal in _called_comps(op):
                if cal in comps:
                    best = max(best, _trip_count(comps[cal], comps,
                                                 _depth + 1))
    return best


def _called_comps(op: Op) -> List[str]:
    out = []
    for m in _CALLED.finditer(op.line):
        out.extend(_OPERAND.findall(m.group(1)))
    return out


def _dot_flops(op: Op, comp: Computation) -> float:
    out_numel = 1
    for d in op.out_dims:
        out_numel *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if not m or not op.operands:
        return 2.0 * out_numel            # degenerate
    cdims = [int(x) for x in m.group(1).split(",") if x]
    lhs = comp.shapes.get(op.operands[0])
    k = 1
    if lhs:
        for c in cdims:
            if c < len(lhs[1]):
                k *= lhs[1][c]
    return 2.0 * out_numel * k


def analyze_hlo(txt: str) -> Dict:
    comps, entry = parse_module(txt)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # propagate multipliers through the call graph (memoized DFS)
    mult: Dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    # BFS with multiplier accumulation; while bodies multiply by trip count
    frontier = [entry]
    while frontier:
        cname = frontier.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m_here = mult[cname]
        for op in comp.ops:
            called = _called_comps(op)
            if not called:
                continue
            trip = 1.0
            cond_name = None
            if op.opcode == "while":
                cond_m = re.search(r"condition=(%[\w.\-]+)", op.line)
                if cond_m:
                    cond_name = cond_m.group(1)
                    if cond_name in comps:
                        trip = float(_trip_count(comps[cond_name], comps))
            for cal in called:
                if op.opcode == "while":
                    # body executes `trip` times, condition `trip + 1`
                    add = m_here * (trip + 1 if cal == cond_name else trip)
                else:
                    add = m_here
                mult[cal] = mult.get(cal, 0.0) + add
                if cal not in seen:
                    seen.add(cal)
                    frontier.append(cal)
                    order.append(cal)

    flops = 0.0
    hbm_bytes = 0.0
    coll = {c: 0.0 for c in _COLLECTIVES}
    coll_counts = {c: 0 for c in _COLLECTIVES}
    fusion_comps = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                fusion_comps.update(_called_comps(op))

    for cname, comp in comps.items():
        m_here = mult.get(cname, 0.0)
        if m_here <= 0:
            continue
        in_fusion = cname in fusion_comps
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                flops += m_here * _dot_flops(op, comp)
            if in_fusion:
                continue                   # fusion internals: no HBM traffic
            if op.opcode in ("parameter", "constant", "get-tuple-element",
                             "tuple", "bitcast",
                             # control ops: traffic is inside their bodies;
                             # the carried tuple is pass-through
                             "while", "call", "conditional"):
                continue
            if op.opcode == "dynamic-slice":
                # reads only the slice (not the full operand buffer)
                hbm_bytes += m_here * 2 * op.out_bytes
                continue
            if op.opcode == "dynamic-update-slice":
                # in-place read-modify-write of the update region
                upd = (comp.shapes.get(op.operands[1], (0, []))[0]
                       if len(op.operands) > 1 else op.out_bytes)
                hbm_bytes += m_here * 2 * upd
                continue
            operand_bytes = sum(comp.shapes.get(o, (0, []))[0]
                                for o in op.operands)
            hbm_bytes += m_here * (op.out_bytes + operand_bytes)
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                coll[base] += m_here * op.out_bytes
                coll_counts[base] += 1

    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collective_bytes": coll,
        "collective_counts": coll_counts,
        "n_computations": len(comps),
    }
