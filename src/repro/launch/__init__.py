"""Launch layer: production meshes, sharding rules, dry-run driver and the
train/serve entry points."""
