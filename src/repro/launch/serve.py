"""Serving driver: batched greedy decoding on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --batch 4 --prompt-len 32 --gen 16

The at-scale serve_step (decode_32k / long_500k) is exercised by
``repro.launch.dryrun``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.models import transformer as T
from repro.train.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = C.get(args.arch) if args.full else C.get_smoke(args.arch)
    key_params, key_prompt, key_gen = jax.random.split(
        jax.random.PRNGKey(args.seed), 3)
    params = T.init_params(cfg, key_params)
    if cfg.embed_stub:
        prompt = 0.1 * jax.random.normal(
            key_prompt, (args.batch, args.prompt_len, cfg.d_model),
            cfg.dtype)
    else:
        prompt = jax.random.randint(
            key_prompt, (args.batch, args.prompt_len), 0, cfg.vocab_size,
            dtype=jnp.int32)

    max_seq = args.prompt_len + args.gen
    t0 = time.time()
    toks = generate(params, cfg, prompt, n_tokens=args.gen, max_seq=max_seq,
                    rng=key_gen, temperature=args.temperature)
    toks.block_until_ready()
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}: {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s incl. compile)")
    print("sample tokens:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
