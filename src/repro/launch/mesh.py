"""Production mesh construction (TPU v5e).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16); the paper's
"workers" map to the pod x data axes (m = 32), so the safeguard's worker
axis spans pods while tensor parallelism stays intra-pod.

Functions, not module constants — importing this module never touches jax
device state (the dry run forces a 512-device host platform *before* any
jax import; tests/benches must keep seeing 1 device).
"""

from __future__ import annotations

import jax


def auto_axis_types(n_axes: int):
    """``axis_types`` kwargs for Mesh/make_mesh, empty on jax versions
    that predate ``jax.sharding.AxisType`` (everything was Auto there)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(jax.devices())} — "
            "the dry run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes,
                             **auto_axis_types(len(axes)))


def worker_axes(mesh) -> tuple:
    """Mesh axes that carry the safeguard worker dimension."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def n_workers(mesh) -> int:
    names = mesh.axis_names
    m = mesh.shape["data"]
    if "pod" in names:
        m *= mesh.shape["pod"]
    return m


def data_size(mesh) -> int:
    return n_workers(mesh)


def model_size(mesh) -> int:
    return mesh.shape["model"]
