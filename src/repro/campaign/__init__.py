"""Campaign engine: vmapped multi-seed scenario subsystem (DESIGN.md §10).

Layers: ``scenario`` (declarative grid cells, content-hashed),
``engine`` (scan-rolled trials vmapped over seed/knob axes),
``store`` (resumable JSONL result store), ``run`` (CLI + built-in
campaign definitions).
"""

from repro.campaign.scenario import (    # noqa: F401
    Scenario, scenario_id, expand_grid, with_seeds)
from repro.campaign.engine import (      # noqa: F401
    batch_key, group_scenarios, run_scenarios)
from repro.campaign.store import CampaignStore    # noqa: F401
