"""Declarative scenario grid for the campaign engine.

A :class:`Scenario` is one cell of the paper's evaluation grid — attack x
defense x alpha x seed plus every knob that changes the trajectory
(optimizer, windows, thresholds, task shape).  It is frozen, fully
JSON-serializable, and content-addressed: :func:`scenario_id` hashes the
dict of *non-default* fields, so the resumable store
(``repro.campaign.store``) can skip cells that already ran, a grid
extended with new attacks/defenses only runs the delta, and adding a new
defaulted knob field to ``Scenario`` later does not re-key existing
cells.

Grid helpers:

* :func:`expand_grid` — cartesian product over axis lists
  (``expand_grid(attack=ATTACKS, defense=DEFENSES, seed=range(5))``);
* :func:`with_seeds` — replicate a scenario list over ``n`` seeds.

The attack/defense *names* are the registry names of ``core.attacks``
and ``core.defenses`` (the unified Defense protocol, DESIGN.md §12 —
historyless baselines, both safeguard variants, and the stateful zoo);
the ``safeguard_x<scale>`` attacks normalize to the ``scaled_flip``
family with a numeric ``attack_scale`` so the engine can batch them
into one vmapped program (``engine.batch_key``).
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from typing import Dict, Iterable, List, Sequence

from repro.core.attacks import ADAPTIVE_DEFAULTS, VARIANCE_Z
from repro.core.defenses import (DEFENSE_DEFAULTS, bucketing_krum_feasible,
                                 derive_bucket_nbyz)
from repro.data.hetero import HETERO_MODELS
from repro.data.saddle import SADDLE_TASKS

# Task families (program structure — each traces its own loss/batch_fn):
# the teacher-student benchmark task plus the planted-saddle testbed
# (DESIGN.md §14).
TASK_MODELS = ("teacher",) + SADDLE_TASKS
# Post-aggregation perturbation modes (train.trainer): "sgd_escape" is
# the paper's isotropic noise injection near stationary points.
PERTURB_MODES = ("none", "sgd_escape")

# The paper's Table 1 grid (Section 5 / Appendix C) — canonical lists,
# re-exported by benchmarks.common for back-compat.
TABLE1_ATTACKS = ("variance", "sign_flip", "label_flip", "delayed",
                  "safeguard_x0.6", "safeguard_x0.7")
TABLE1_DEFENSES = ("safeguard_single", "safeguard_double", "coord_median",
                   "geo_median", "krum", "zeno", "mean")
# Feedback-coupled adversaries (DESIGN.md §11) — names in the
# core.attacks registry; their adapt_* knobs are vmap axes.
ADAPTIVE_ATTACKS = ("adaptive_flip", "adaptive_variance", "oscillating",
                    "median_capture")
# History-aware defense zoo (DESIGN.md §12) — stateful defenses beyond
# the paper's grid; their clip/spectral knobs are vmap axes.
ZOO_DEFENSES = ("centered_clip", "norm_filter", "dnc", "safeguard_cclip")
# The heterogeneity campaign's defense suite (DESIGN.md §13): the
# selection-style rules that suffer under non-IID honest workers, the
# bounded-influence rules that do not, and bucketing as the repair.
HETERO_DEFENSES = ("mean", "krum", "trimmed_mean", "centered_clip",
                   "bucketing_krum", "safeguard_double")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One evaluation cell.  Defaults mirror the CPU-scale protocol of
    ``benchmarks/common.py`` (m=10, alpha=0.4, teacher-student task)."""
    attack: str
    defense: str
    # population
    m: int = 10
    n_byz: int = 4
    # trial length / optimization
    steps: int = 150
    seed: int = 0
    lr: float = 0.1
    batch: int = 100
    optimizer: str = "sgd"
    momentum: float = 0.0
    # safeguard knobs (ignored for baseline aggregator defenses)
    T0: int = 20
    T1: int = 120
    threshold_floor: float = 0.1
    # empirical-filter eviction multiplier (vmap axis like the floor);
    # default is the paper's IID calibration, the hetero campaign runs a
    # zeta-relaxed lane (DESIGN.md §13)
    threshold_scale: float = DEFENSE_DEFAULTS["threshold_scale"]
    reset_period: int = 0
    # attack knobs
    attack_scale: float = 0.0     # scaled_flip family; 0 -> from the name
    delay: int = 32               # delayed attack circular-buffer length
    burst_start: int = -1         # -1: derive from trial length (steps // 3)
    burst_length: int = 50
    # adaptive-attack knobs (vmap axes, engine.stack_knobs): initial
    # scale/z/eps, ramp-up multiplier, caught back-off multiplier, and the
    # threshold fraction the tracker aims at — defaults are the single
    # source shared with the make_adaptive_* factories (core.attacks)
    adapt_init: float = ADAPTIVE_DEFAULTS["adapt_init"]
    adapt_rate: float = ADAPTIVE_DEFAULTS["adapt_rate"]
    adapt_down: float = ADAPTIVE_DEFAULTS["adapt_down"]
    adapt_target: float = ADAPTIVE_DEFAULTS["adapt_target"]
    # stateful-defense knobs (vmap axes, engine.stack_knobs): centered
    # clipping radius/momentum and the DnC power-iteration budget —
    # defaults are the single source shared with the defense factories
    # (core.defenses.DEFENSE_DEFAULTS)
    clip_tau: float = DEFENSE_DEFAULTS["clip_tau"]
    clip_beta: float = DEFENSE_DEFAULTS["clip_beta"]
    spectral_iters: int = DEFENSE_DEFAULTS["spectral_iters"]
    # worker-heterogeneity model (DESIGN.md §13): the model name is
    # program structure (each mode traces its own batch_fn; "iid" is
    # exactly the pre-heterogeneity path), the knobs are vmap axes
    hetero: str = "iid"
    hetero_alpha: float = 0.0     # Dirichlet label-skew concentration;
    #                               <= 0 and inf both mean IID (bit-exact)
    hetero_shift: float = 0.0     # teacher-rotation concept shift, radians
    # bucketing meta-defense: workers per bucket — static shape structure
    # (the wrapped aggregator runs on m / bucket_s rows), so it is part
    # of batch_key for bucketing_* defenses, never a vmap knob
    bucket_s: int = DEFENSE_DEFAULTS["bucket_s"]
    # task family (program structure, batch_key): "teacher" is the
    # pre-saddle path; "saddle_quad"/"saddle_chain" are the planted-
    # saddle testbed (DESIGN.md §14) with dimension d_in and knobs below
    task: str = "teacher"
    # planted-saddle knobs (vmap axes, engine.stack_knobs): curvature
    # gap (lambda_min = -saddle_gap at the saddle), gradient-noise
    # radius, and the Byzantine-SVRG anchor period (0/1 = plain SGD)
    saddle_gap: float = 0.5
    noise_r: float = 0.05
    vr_period: int = 0
    # saddle-escape perturbation (train.trainer): the mode is program
    # structure (extra rng split), the noise scale / near-stationary
    # gate are vmap knob axes
    perturb: str = "none"
    escape_nu: float = 0.01
    escape_thresh: float = 0.1
    # teacher-student task shape
    d_in: int = 32
    d_hidden: int = 64
    n_classes: int = 10
    task_seed: int = 0

    def __post_init__(self):
        # loud, construction-time validation: these used to surface as a
        # worker_split reshape error (or a bucket-shape error) from the
        # middle of a traced scan, steps away from the bad grid axis
        if self.m > 0 and self.batch % self.m:
            raise ValueError(
                f"scenario {self.attack}/{self.defense} (seed={self.seed}): "
                f"batch={self.batch} is not divisible by m={self.m} — "
                "worker_split would fail mid-scan")
        if self.hetero not in HETERO_MODELS:
            raise ValueError(
                f"scenario {self.attack}/{self.defense}: unknown hetero "
                f"model {self.hetero!r} (one of {HETERO_MODELS})")
        if self.task not in TASK_MODELS:
            raise ValueError(
                f"scenario {self.attack}/{self.defense}: unknown task "
                f"{self.task!r} (one of {TASK_MODELS})")
        if self.perturb not in PERTURB_MODES:
            raise ValueError(
                f"scenario {self.attack}/{self.defense}: unknown perturb "
                f"mode {self.perturb!r} (one of {PERTURB_MODES})")
        if self.task in SADDLE_TASKS:
            if self.attack == "label_flip":
                raise ValueError(
                    f"scenario {self.attack}/{self.defense}: label_flip "
                    "is a data attack — the planted-saddle task has no "
                    "labels to flip")
            if self.hetero != "iid":
                raise ValueError(
                    f"scenario {self.attack}/{self.defense}: hetero model "
                    f"{self.hetero!r} is a teacher-task axis — the saddle "
                    "testbed's noise model is IID by construction")
        elif self.attack == "saddle_push":
            raise ValueError(
                f"scenario {self.attack}/{self.defense}: saddle_push "
                "needs the planted escape directions — task must be one "
                f"of {SADDLE_TASKS}, got {self.task!r}")
        if self.bucket_s < 1:
            # validated for EVERY defense: the engine forwards bucket_s
            # to make_registry unconditionally, where 0 would be an
            # unnamed ZeroDivisionError mid-campaign
            raise ValueError(
                f"scenario {self.attack}/{self.defense}: bucket_s="
                f"{self.bucket_s} must be >= 1")
        if self.defense.startswith("bucketing"):
            if self.m % self.bucket_s:
                raise ValueError(
                    f"scenario {self.attack}/{self.defense}: m={self.m} is "
                    f"not divisible by bucket_s={self.bucket_s}")
            if self.m // self.bucket_s < 3:
                raise ValueError(
                    f"scenario {self.attack}/{self.defense}: bucket_s="
                    f"{self.bucket_s} leaves only {self.m // self.bucket_s}"
                    " buckets (< 3) — the wrapped rule has nothing to "
                    "aggregate over")
            if (self.defense == "bucketing_krum"
                    and not bucketing_krum_feasible(self.m, self.n_byz,
                                                    self.bucket_s)):
                # the registry's feasibility gate (single source), here
                # so an unsound combination fails scenario-named at
                # construction instead of as "unknown defense" from the
                # engine mid-campaign
                raise ValueError(
                    f"scenario {self.attack}/{self.defense}: "
                    f"ceil(n_byz/bucket_s)="
                    f"{derive_bucket_nbyz(self.n_byz, self.bucket_s)} "
                    "corrupt buckets exceed what inner Krum tolerates on "
                    f"{self.m // self.bucket_s} buckets (needs m > b + 2)")

    def asdict(self) -> Dict:
        return dataclasses.asdict(self)


# field -> default value; fields without a default (attack, defense) are
# always part of the hash blob
_FIELD_DEFAULTS = {
    name: f.default for name, f in Scenario.__dataclass_fields__.items()
    if f.default is not dataclasses.MISSING
}
_MISSING = object()


def scenario_id(s: Scenario) -> str:
    """Stable content hash of the scenario — the store key.

    Fields sitting at their default value are EXCLUDED from the hash
    blob, so growing ``Scenario`` by a new defaulted knob later does not
    re-key (and thereby orphan) every previously stored cell whose
    execution is unchanged.

    Constants that change a cell's *semantics* without being Scenario
    fields are folded into the hash for exactly the cells they govern:
    the variance attack's collusion strength ``attacks.VARIANCE_Z`` is
    part of every ``variance`` cell's key, so recalibrating it (z 0.3 ->
    1.5 in this repo's history) orphans precisely the stale variance
    rows of a persisted store instead of silently mixing strengths in a
    resumed grid."""
    fields = {k: v for k, v in s.asdict().items()
              if _FIELD_DEFAULTS.get(k, _MISSING) != v}
    if s.attack == "variance":
        fields["_variance_z"] = VARIANCE_Z
    blob = json.dumps(fields, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def expand_grid(base: Scenario | None = None, **axes: Sequence) -> List[Scenario]:
    """Cartesian product over ``axes`` (field name -> list of values),
    starting from ``base`` (or field defaults).  Axis order follows the
    kwargs, so the first axis varies slowest — deterministic cell order.

    ``expand_grid(attack=["variance"], defense=TABLE1_DEFENSES,
    seed=range(5))`` -> 35 scenarios.
    """
    names = list(axes)
    for name in names:
        if name not in Scenario.__dataclass_fields__:
            raise ValueError(f"unknown Scenario field {name!r}")
    out = []
    for combo in itertools.product(*(axes[n] for n in names)):
        fields = dict(zip(names, combo))
        if base is None:
            if "attack" not in fields or "defense" not in fields:
                raise ValueError("grid without a base scenario needs "
                                 "attack and defense axes")
            out.append(Scenario(**fields))
        else:
            out.append(dataclasses.replace(base, **fields))
    return out


def with_seeds(scenarios: Iterable[Scenario], n_seeds: int) -> List[Scenario]:
    """Replicate every scenario over seeds ``0..n_seeds-1`` (the engine
    turns the seed axis into vmap lanes, so replication is nearly free)."""
    return [dataclasses.replace(s, seed=k)
            for s in scenarios for k in range(n_seeds)]
