"""Resumable campaign result store.

One directory per campaign under ``experiments/campaigns/<name>/``:

  meta.json       campaign name + last launch parameters (informational)
  results.jsonl   one line per completed cell:
                  {"id": <scenario hash>, "scenario": {...}, "result": {...}}

The store is content-addressed by :func:`scenario.scenario_id`, so

* re-running a campaign skips every completed cell (``pending`` filters
  against ``completed_ids``);
* extending the grid (new attacks, defenses, seeds, knob values) only
  runs the delta — new cells hash to new ids;
* the file is append-only and crash-safe per line: a partially-written
  trailing line (killed run) is ignored on load, and duplicate ids keep
  the last record.

Result payloads are scalars by default; per-step traces are optional
(``store_traces=True`` on :meth:`CampaignStore.append`).  Traces live in
compressed ``.npz`` sidecars under ``traces/<scenario_id>.npz``
(``repro.obs.trace``) — the JSONL record carries only the sidecar's
relative path and field list, plus the cell's extracted event log
(``repro.obs.events``), which is small.  :meth:`CampaignStore.
load_traces` reads sidecars and falls back to the legacy JSONL-inlined
``result["traces"]`` dicts of pre-obs campaigns.
"""

from __future__ import annotations

import json
import os
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.campaign.scenario import Scenario, scenario_id
from repro.obs import trace as trace_lib

DEFAULT_ROOT = os.path.join("experiments", "campaigns")


def _jsonify(x, _path: str = "$"):
    """numpy / jax scalars and arrays -> plain json types.

    Total over the types a result payload may legally contain; anything
    else (a function, a Scenario, a device buffer that isn't
    array-like) raises :class:`TypeError` naming the offending path —
    an unknown type passed through silently used to serialize as its
    ``repr`` or crash ``json.dumps`` a layer later, pointing at nothing.

    NaN / ±inf are kept as floats: the store uses python's json module,
    which round-trips them (``NaN``/``Infinity`` literals)."""
    if x is None or isinstance(x, str):
        return x
    if isinstance(x, dict):
        return {str(k): _jsonify(v, f"{_path}.{k}") for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonify(v, f"{_path}[{i}]") for i, v in enumerate(x)]
    if isinstance(x, np.ndarray):
        return _jsonify(x.tolist(), _path)
    # bool before int: bool is a subclass of int, np.bool_ of np.generic
    if isinstance(x, (np.bool_, bool)):
        return bool(x)
    if isinstance(x, (np.integer, int)):
        return int(x)
    if isinstance(x, (np.floating, float)):
        return float(x)
    if isinstance(x, np.generic):     # remaining numpy scalar kinds
        return _jsonify(x.item(), _path)
    if hasattr(x, "tolist"):          # jax arrays (incl. 0-d)
        return _jsonify(np.asarray(x).tolist(), _path)
    raise TypeError(
        f"_jsonify: {_path} has unserializable type {type(x).__name__}; "
        "result payloads may only contain json scalars, lists/dicts, and "
        "numpy/jax arrays")


class CampaignStore:
    def __init__(self, name: str, root: str = DEFAULT_ROOT):
        self.name = name
        self.dir = os.path.join(root, name)
        self.path = os.path.join(self.dir, "results.jsonl")
        os.makedirs(self.dir, exist_ok=True)

    # -- reading -----------------------------------------------------------

    def load(self) -> Dict[str, Dict]:
        """id -> record; tolerates a torn trailing line, last record wins."""
        records: Dict[str, Dict] = {}
        if not os.path.exists(self.path):
            return records
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue                     # torn write from a kill
                if "id" in rec:
                    records[rec["id"]] = rec
        return records

    def completed_ids(self) -> set:
        return set(self.load())

    def pending(self, scenarios: Sequence[Scenario]) -> List[Scenario]:
        done = self.completed_ids()
        return [s for s in scenarios if scenario_id(s) not in done]

    def load_traces(self, sid: str) -> Optional[Dict[str, np.ndarray]]:
        """A cell's dense traces: ``.npz`` sidecar if the record names
        one, legacy JSONL-inlined dict otherwise, None if untraced."""
        rec = self.load().get(sid)
        if rec is None:
            return None
        return trace_lib.load_cell_traces(self.dir, rec)

    # -- writing -----------------------------------------------------------

    def append(self, scenario: Scenario, result: Dict, *,
               store_traces: bool = False) -> str:
        sid = scenario_id(scenario)
        payload = {k: v for k, v in result.items() if k != "traces"}
        if store_traces and "traces" in result:
            # dense traces go to a compressed sidecar, not the JSONL:
            # the record carries only the pointer + field list
            payload["trace_file"] = trace_lib.save_traces(
                self.dir, sid, result["traces"])
            payload["trace_fields"] = sorted(result["traces"])
        rec = {"id": sid, "scenario": scenario.asdict(),
               "result": _jsonify(payload)}
        with open(self.path, "a") as f:
            f.write(json.dumps(rec) + "\n")
        return sid

    def write_meta(self, meta: Dict) -> None:
        with open(os.path.join(self.dir, "meta.json"), "w") as f:
            json.dump(_jsonify(meta), f, indent=1)
            f.write("\n")
