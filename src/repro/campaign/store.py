"""Resumable campaign result store.

One directory per campaign under ``experiments/campaigns/<name>/``:

  meta.json       campaign name + last launch parameters (informational)
  results.jsonl   one line per completed cell:
                  {"id": <scenario hash>, "scenario": {...}, "result": {...}}

The store is content-addressed by :func:`scenario.scenario_id`, so

* re-running a campaign skips every completed cell (``pending`` filters
  against ``completed_ids``);
* extending the grid (new attacks, defenses, seeds, knob values) only
  runs the delta — new cells hash to new ids;
* the file is append-only and crash-safe per line: a partially-written
  trailing line (killed run) is ignored on load, and duplicate ids keep
  the last record.

Result payloads are scalars by default; per-step traces are optional
(``store_traces=True`` on :meth:`CampaignStore.append`) since a trace is
``steps`` floats per metric per cell.
"""

from __future__ import annotations

import json
import os
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.campaign.scenario import Scenario, scenario_id

DEFAULT_ROOT = os.path.join("experiments", "campaigns")


def _jsonify(x):
    """numpy / jax scalars and arrays -> plain json types."""
    if isinstance(x, dict):
        return {k: _jsonify(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonify(v) for v in x]
    if isinstance(x, np.ndarray):
        return _jsonify(x.tolist())
    if isinstance(x, (np.bool_, bool)):
        return bool(x)
    if isinstance(x, (np.integer, int)):
        return int(x)
    if isinstance(x, (np.floating, float)):
        return float(x)
    if hasattr(x, "tolist"):          # jax arrays
        return _jsonify(np.asarray(x).tolist())
    return x


class CampaignStore:
    def __init__(self, name: str, root: str = DEFAULT_ROOT):
        self.name = name
        self.dir = os.path.join(root, name)
        self.path = os.path.join(self.dir, "results.jsonl")
        os.makedirs(self.dir, exist_ok=True)

    # -- reading -----------------------------------------------------------

    def load(self) -> Dict[str, Dict]:
        """id -> record; tolerates a torn trailing line, last record wins."""
        records: Dict[str, Dict] = {}
        if not os.path.exists(self.path):
            return records
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue                     # torn write from a kill
                if "id" in rec:
                    records[rec["id"]] = rec
        return records

    def completed_ids(self) -> set:
        return set(self.load())

    def pending(self, scenarios: Sequence[Scenario]) -> List[Scenario]:
        done = self.completed_ids()
        return [s for s in scenarios if scenario_id(s) not in done]

    # -- writing -----------------------------------------------------------

    def append(self, scenario: Scenario, result: Dict, *,
               store_traces: bool = False) -> str:
        sid = scenario_id(scenario)
        payload = {k: v for k, v in result.items()
                   if k != "traces" or store_traces}
        rec = {"id": sid, "scenario": scenario.asdict(),
               "result": _jsonify(payload)}
        with open(self.path, "a") as f:
            f.write(json.dumps(rec) + "\n")
        return sid

    def write_meta(self, meta: Dict) -> None:
        with open(os.path.join(self.dir, "meta.json"), "w") as f:
            json.dump(_jsonify(meta), f, indent=1)
            f.write("\n")
