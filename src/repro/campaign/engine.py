"""Batched trial engine: one device program per scenario *family*.

The legacy path (``benchmarks/common.run_experiment_loop``) runs one jit
and ~150 python-dispatched steps per grid cell.  The engine instead:

1. rolls a whole trial into one ``lax.scan`` (``train.trainer.scan_trial``
   — the step carry already threads optimizer/safeguard/attack state, and
   the seeded synthetic data pipeline regenerates each batch inside the
   scan body from the step index, bit-compatible with the python
   iterators in ``repro.data``);
2. ``vmap``s the trial over every scenario axis that is a *traced knob*
   rather than program structure — the seed axis always, plus
   ``attack_scale`` (all ``scaled_flip``/``safeguard_x*`` variants),
   ``threshold_floor`` (safeguard defenses), ``n_byz`` (defenses that do
   not consume b statically), the ``adapt_*`` controller knobs of the
   feedback-coupled adaptive attacks (DESIGN.md §11), the
   ``clip_tau``/``clip_beta``/``spectral_iters`` knobs of the stateful
   defense zoo (DESIGN.md §12), and the ``hetero_alpha``/``hetero_shift``
   knobs of the worker-heterogeneity models (DESIGN.md §13 — the hetero
   *mode* and ``bucket_s`` are program structure and live in the key);
3. groups scenarios by :func:`batch_key` — everything that changes the
   traced program (attack family, defense, m, steps, windows, task shape)
   — so a 6x7x5-seed Table-1 grid compiles ~35 programs instead of
   dispatching ~200 python trials.

Which axes may share a program: two scenarios batch together iff their
``batch_key`` matches, i.e. they differ only in the four knobs above.
``krum``/``trimmed_mean``/``zeno`` consume ``n_byz`` as a static python
value (slice bounds), so for those defenses ``n_byz`` is part of the key
instead of a knob.

Per-step metric traces (loss, good-set size, caught-Byzantine count, ...)
come out of the scan stacked on device, so multi-seed statistics and the
Fig-2 trajectories are one ``device_get`` away.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.campaign.scenario import Scenario, scenario_id
from repro.configs.base import TrainConfig
from repro.core import attacks as atk_lib
from repro.core import defenses as dfn_lib
from repro.data import hetero as het_lib
from repro.data import saddle as sad_lib
from repro.data import tasks
from repro.data.pipeline import flip_labels, worker_split
from repro.obs import events as ev_lib
from repro.optim import make_optimizer
from repro.train import init_train_state, make_train_step, scan_trial

# Defenses that consume n_byz as a static python value (slice/selection
# bounds) — n_byz is program structure for them, a vmap knob otherwise.
# Derived from the Defense protocol registry (single source).
STATIC_NBYZ_DEFENSES = dfn_lib.static_nbyz_names()

EVAL_BATCH = 4000            # final-accuracy eval batch (common.py protocol)
EVAL_KEY = 10_000


def attack_family(s: Scenario) -> Tuple[str, float]:
    """Normalize the attack name to (family, scale): ``safeguard_x0.6`` ->
    ``("scaled_flip", 0.6)`` so all scale variants share one program."""
    if s.attack.startswith("safeguard_x"):
        return "scaled_flip", float(s.attack[len("safeguard_x"):])
    if s.attack == "scaled_flip":
        return "scaled_flip", float(s.attack_scale)
    return s.attack, 0.0


def batch_key(s: Scenario) -> Tuple:
    """Scenarios with equal keys run as lanes of one vmapped program."""
    fam, _ = attack_family(s)
    return (fam, s.defense, s.m, s.steps, s.lr, s.batch, s.optimizer,
            s.momentum, s.T0, s.T1, s.reset_period, s.delay, s.burst_start,
            s.burst_length, s.d_in, s.d_hidden, s.n_classes, s.task_seed,
            s.hetero, s.task, s.perturb,
            s.bucket_s if s.defense.startswith("bucketing") else None,
            s.n_byz if s.defense in STATIC_NBYZ_DEFENSES else None)


def _build_attack(family: str, rep: Scenario, knobs,
                  saddle_task=None) -> atk_lib.Attack:
    """Instantiate the attack from the vmappable ``knobs`` dict — the
    scale and adapt_* entries may be traced scalars (the attack closures
    only do arithmetic with them).  ``saddle_task`` carries the planted
    directions the task-coupled ``saddle_push`` needs (DESIGN.md §14)."""
    if family == "saddle_push":
        if saddle_task is None:
            raise ValueError("saddle_push needs a planted-saddle task")
        return atk_lib.make_saddle_push(
            saddle_task.dirs, boost_init=knobs["adapt_init"],
            up=knobs["adapt_rate"], down=knobs["adapt_down"],
            target=knobs["adapt_target"])
    if family == "scaled_flip":
        return atk_lib.Attack("scaled_flip",
                              atk_lib.make_scaled_flip(knobs["attack_scale"]))
    if family == "adaptive_flip":
        return atk_lib.make_adaptive_flip(
            init_scale=knobs["adapt_init"], up=knobs["adapt_rate"],
            down=knobs["adapt_down"], target=knobs["adapt_target"])
    if family == "adaptive_variance":
        return atk_lib.make_adaptive_variance(
            z_init=knobs["adapt_init"], up=knobs["adapt_rate"],
            down=knobs["adapt_down"])
    if family == "oscillating":
        return atk_lib.make_oscillating(
            init_scale=knobs["adapt_init"], up=knobs["adapt_rate"],
            high=knobs["adapt_target"], low=0.5 * knobs["adapt_target"],
            down=knobs["adapt_down"])
    if family == "median_capture":
        return atk_lib.make_median_capture(
            eps_init=knobs["adapt_init"], up=knobs["adapt_rate"],
            down=knobs["adapt_down"])
    if family == "delayed":
        fn = atk_lib.make_delayed(rep.delay)
        return atk_lib.Attack("delayed", fn, init=fn.init)
    if family == "burst":
        # window derivation + never-fires validation live in make_registry
        # (single source, shared with the legacy Trainer path)
        return atk_lib.make_registry(
            delay=rep.delay,
            burst_start=None if rep.burst_start < 0 else rep.burst_start,
            burst_length=rep.burst_length, steps=rep.steps)["burst"]
    registry = atk_lib.make_registry(delay=rep.delay, steps=rep.steps)
    if family not in registry:
        raise ValueError(f"unknown attack {family!r}")
    return registry[family]


def _build_defense(rep: Scenario, knobs) -> dfn_lib.Defense:
    """Instantiate the defense from the vmappable ``knobs`` dict — the
    floor/clip/spectral knobs (and ``n_byz`` for non-static defenses)
    may be traced scalars: they only feed arithmetic inside
    ``Defense.aggregate`` (DESIGN.md §12)."""
    static = rep.defense in STATIC_NBYZ_DEFENSES
    reg = dfn_lib.make_registry(
        rep.m, rep.n_byz if static else knobs["n_byz"],
        T0=rep.T0, T1=rep.T1, threshold_floor=knobs["threshold_floor"],
        threshold_scale=knobs["threshold_scale"],
        reset_period=rep.reset_period, clip_tau=knobs["clip_tau"],
        clip_beta=knobs["clip_beta"],
        spectral_iters=knobs["spectral_iters"], bucket_s=rep.bucket_s)
    if rep.defense not in reg:
        raise ValueError(f"unknown defense {rep.defense!r}")
    return reg[rep.defense]


def fit_tap_every(steps: int, tap_every: int) -> int:
    """Largest divisor of ``steps`` that is <= ``tap_every`` —
    ``scan_trial`` requires windows to tile the trial exactly, and the
    campaign CLI should not have to care that ``--quick`` shrinks
    ``steps`` below the default tap period."""
    if tap_every <= 0:
        return 0
    for k in range(min(tap_every, steps), 0, -1):
        if steps % k == 0:
            return k
    return 0


def _tap_kwargs(rep: Scenario, knobs, tap, tap_every: int) -> Dict:
    """The ``scan_trial`` tap wiring for one trial: the window period
    fitted to the trial length, and the vmap lane index threaded into
    every payload (the host callback's only lane identity)."""
    if not tap_every or tap is None:
        return {}
    return {"tap_every": fit_tap_every(rep.steps, tap_every), "tap": tap,
            "tap_meta": {"lane": knobs["lane"]} if "lane" in knobs
            else None}


def make_trial_fn(rep: Scenario, *, tap=None, tap_every: int = 0):
    """Build ``trial(knobs) -> result`` for the family ``rep`` represents.

    ``knobs`` is the dict of vmappable scalars built by
    :func:`stack_knobs` (seed, attack/filter/defense knobs, the hetero
    and saddle knobs).  Everything else about ``rep`` is baked into the
    traced program, which is why only scenarios sharing
    :func:`batch_key` may be stacked into one call.

    ``tap``/``tap_every`` stream the live-telemetry heartbeat out of
    the scan (DESIGN.md §17) — semantics-free: the tapped program's
    step sequence is bit-identical to the untapped one.
    """
    if rep.task in sad_lib.SADDLE_TASKS:
        return _make_saddle_trial_fn(rep, tap=tap, tap_every=tap_every)
    family, _ = attack_family(rep)
    task = tasks.make_teacher_task(rep.d_in, rep.d_hidden, rep.n_classes,
                                   seed=rep.task_seed)
    opt = make_optimizer(TrainConfig(lr=rep.lr, momentum=rep.momentum,
                                     optimizer=rep.optimizer))
    data_attack = family == "label_flip"
    dynamic_nbyz = rep.defense not in STATIC_NBYZ_DEFENSES

    def trial(knobs):
        seed = knobs["seed"]
        n_byz = knobs["n_byz"] if dynamic_nbyz else rep.n_byz
        byz_mask = jnp.arange(rep.m) < n_byz
        attack = _build_attack(family, rep, knobs)
        defense = _build_defense(rep, knobs)

        params = tasks.student_init(task, seed=seed + 1)
        state = init_train_state(params, opt, defense=defense,
                                 attack=attack, seed=seed)
        step_fn = make_train_step(tasks.mlp_loss, opt, byz_mask=byz_mask,
                                  defense=defense, attack=attack,
                                  perturb=rep.perturb,
                                  escape_nu=knobs["escape_nu"],
                                  escape_thresh=knobs["escape_thresh"],
                                  jit=False)

        # In-scan data generation, bit-compatible with the python
        # iterators ``tasks.teacher_batches`` / ``hetero.hetero_batches``
        # (same key schedule; the "iid" mode is the pre-heterogeneity
        # path, traced without any hetero machinery).
        mix_w = None
        if rep.hetero == "dirichlet":
            # per-trial mixture draw (traced: seed and alpha are lanes)
            mix_w = het_lib.worker_mixtures(
                het_lib.mixture_key(seed), knobs["hetero_alpha"], rep.m,
                rep.n_classes)

        def batch_fn(t):
            key = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0xDA7A), t)
            if rep.hetero == "iid":
                out = worker_split(tasks.teacher_batch(task, key,
                                                       rep.batch), rep.m)
            else:
                out = het_lib.hetero_worker_batch(
                    task, key, rep.batch, rep.m, mode=rep.hetero,
                    weights=mix_w, alpha=knobs["hetero_alpha"],
                    shift=knobs["hetero_shift"])
            if data_attack:
                flipped = flip_labels(out["y"], rep.n_classes)
                sel = byz_mask.reshape((rep.m, 1))
                out = {"x": out["x"], "y": jnp.where(sel, flipped, out["y"])}
            return out

        held_fn = None
        if defense.needs_held_batch:
            def held_fn(t):  # noqa: E306 — teacher_batches(task, 10, seed+7)
                key = jax.random.fold_in(
                    jax.random.PRNGKey((seed + 7) ^ 0xDA7A), t)
                return tasks.teacher_batch(task, key, 10)

        final, traces = scan_trial(step_fn, state, batch_fn=batch_fn,
                                   steps=rep.steps, held_fn=held_fn,
                                   **_tap_kwargs(rep, knobs, tap,
                                                 tap_every))

        eval_b = tasks.teacher_batch(task, jax.random.PRNGKey(EVAL_KEY),
                                     EVAL_BATCH)
        out = {"acc": tasks.mlp_accuracy(final.params, eval_b),
               "traces": traces}
        good = dfn_lib.final_good(final.defense_state)
        if good is not None:
            out["final_good"] = good
            out["caught_byz"] = (byz_mask & ~good).sum()
            out["evicted_honest"] = (~byz_mask & ~good).sum()
        return out

    return trial


def _make_saddle_trial_fn(rep: Scenario, *, tap=None, tap_every: int = 0):
    """Trial builder for the planted-saddle task family (DESIGN.md §14).

    Program structure: the task kind, its planted directions, and the
    ``perturb`` mode.  Traced knobs: ``saddle_gap`` / ``noise_r`` /
    ``vr_period`` / ``escape_nu`` / ``escape_thresh`` — all pure
    arithmetic inside the loss, batch_fn, probe, and trainer, so every
    gap/noise/VR variant of one kind is a lane of the same program.
    """
    family, _ = attack_family(rep)
    stask = sad_lib.make_saddle_task(rep.d_in, rep.task, seed=rep.task_seed)
    opt = make_optimizer(TrainConfig(lr=rep.lr, momentum=rep.momentum,
                                     optimizer=rep.optimizer))
    dynamic_nbyz = rep.defense not in STATIC_NBYZ_DEFENSES

    def trial(knobs):
        seed = knobs["seed"]
        n_byz = knobs["n_byz"] if dynamic_nbyz else rep.n_byz
        byz_mask = jnp.arange(rep.m) < n_byz
        attack = _build_attack(family, rep, knobs, saddle_task=stask)
        defense = _build_defense(rep, knobs)
        gap = knobs["saddle_gap"]

        loss_fn = sad_lib.make_saddle_loss(stask, gap, knobs["noise_r"])
        params = sad_lib.x_init(stask)
        state = init_train_state(params, opt, defense=defense,
                                 attack=attack, seed=seed)
        step_fn = make_train_step(loss_fn, opt, byz_mask=byz_mask,
                                  defense=defense, attack=attack,
                                  perturb=rep.perturb,
                                  escape_nu=knobs["escape_nu"],
                                  escape_thresh=knobs["escape_thresh"],
                                  so_probe=sad_lib.make_probe(stask, gap),
                                  jit=False)

        def batch_fn(t):
            ta = sad_lib.anchor_step(t, knobs["vr_period"])
            return sad_lib.saddle_batch(
                stask, sad_lib.step_key(seed, ta), rep.batch, rep.m,
                scale=sad_lib.vr_scale(knobs["vr_period"]))

        held_fn = None
        if defense.needs_held_batch:
            def held_fn(t):  # noqa: E306 — unsplit 10-sample noise batch
                key = jax.random.fold_in(
                    jax.random.PRNGKey((seed + 7) ^ 0xDA7A), t)
                return {"eps": jax.random.normal(key, (10, stask.d),
                                                 jnp.float32)}

        final, traces = scan_trial(step_fn, state, batch_fn=batch_fn,
                                   steps=rep.steps, held_fn=held_fn,
                                   **_tap_kwargs(rep, knobs, tap,
                                                 tap_every))

        # "acc" for a saddle task is the escape predicate on the final
        # iterate, so every downstream table/store path works unchanged
        out = {"acc": sad_lib.escaped(stask, final.params["x"],
                                      gap).astype(jnp.float32),
               "traces": traces}
        good = dfn_lib.final_good(final.defense_state)
        if good is not None:
            out["final_good"] = good
            out["caught_byz"] = (byz_mask & ~good).sum()
            out["evicted_honest"] = (~byz_mask & ~good).sum()
        return out

    return trial


def stack_knobs(group: Sequence[Scenario]) -> Dict[str, jax.Array]:
    for s in group:
        if s.spectral_iters > dfn_lib.MAX_SPECTRAL_ITERS:
            # the lane value is traced by the time make_dnc sees it, so
            # the factory's own concrete-value check cannot fire here
            raise ValueError(
                f"spectral_iters={s.spectral_iters} exceeds "
                f"MAX_SPECTRAL_ITERS={dfn_lib.MAX_SPECTRAL_ITERS} and "
                "would silently truncate")
    return {
        "seed": jnp.asarray([s.seed for s in group], jnp.int32),
        "attack_scale": jnp.asarray([attack_family(s)[1] for s in group],
                                    jnp.float32),
        "threshold_floor": jnp.asarray([s.threshold_floor for s in group],
                                       jnp.float32),
        "threshold_scale": jnp.asarray([s.threshold_scale for s in group],
                                       jnp.float32),
        "n_byz": jnp.asarray([s.n_byz for s in group], jnp.int32),
        # adaptive-attack controller knobs (DESIGN.md §11) — pure
        # arithmetic inside the observe/act closures, so every adaptive
        # variant of one family is a lane of the same program
        "adapt_init": jnp.asarray([s.adapt_init for s in group],
                                  jnp.float32),
        "adapt_rate": jnp.asarray([s.adapt_rate for s in group],
                                  jnp.float32),
        "adapt_down": jnp.asarray([s.adapt_down for s in group],
                                  jnp.float32),
        "adapt_target": jnp.asarray([s.adapt_target for s in group],
                                    jnp.float32),
        # stateful-defense knobs (DESIGN.md §12) — pure arithmetic inside
        # Defense.aggregate, so every clip/spectral variant of one
        # defense is a lane of the same program
        "clip_tau": jnp.asarray([s.clip_tau for s in group], jnp.float32),
        "clip_beta": jnp.asarray([s.clip_beta for s in group],
                                 jnp.float32),
        "spectral_iters": jnp.asarray([s.spectral_iters for s in group],
                                      jnp.int32),
        # worker-heterogeneity knobs (DESIGN.md §13) — the Dirichlet
        # concentration and the concept-shift angle feed only fixed-shape
        # sampling arithmetic inside the hetero batch_fn, so every alpha
        # / shift variant of one hetero mode is a lane of the same
        # program (inf is a valid lane value: exact-IID sentinel)
        "hetero_alpha": jnp.asarray([s.hetero_alpha for s in group],
                                    jnp.float32),
        "hetero_shift": jnp.asarray([s.hetero_shift for s in group],
                                    jnp.float32),
        # planted-saddle knobs (DESIGN.md §14) — curvature gap, noise
        # radius, SVRG anchor period, and the sgd_escape perturbation
        # knobs all feed only arithmetic inside the saddle loss /
        # batch_fn / probe / trainer, so every gap / noise / VR variant
        # of one task kind is a lane of the same program
        "saddle_gap": jnp.asarray([s.saddle_gap for s in group],
                                  jnp.float32),
        "noise_r": jnp.asarray([s.noise_r for s in group], jnp.float32),
        "vr_period": jnp.asarray([s.vr_period for s in group], jnp.int32),
        "escape_nu": jnp.asarray([s.escape_nu for s in group],
                                 jnp.float32),
        "escape_thresh": jnp.asarray([s.escape_thresh for s in group],
                                     jnp.float32),
    }


def group_scenarios(scenarios: Sequence[Scenario]
                    ) -> List[List[Scenario]]:
    """Partition by :func:`batch_key`, preserving first-seen order."""
    groups: Dict[Tuple, List[Scenario]] = {}
    for s in scenarios:
        groups.setdefault(batch_key(s), []).append(s)
    return list(groups.values())


def cell_label(s: Scenario) -> str:
    """Human-readable heartbeat cell name: attack/defense/seed plus a
    scenario-hash prefix (keeps labels unique across knob variants and
    joinable back to the store's full ``scenario_id``)."""
    return f"{s.attack}-{s.defense}-seed{s.seed}-{scenario_id(s)[:8]}"


def _lane_record(lane: Dict) -> Dict:
    """One host-side trial output pytree -> result record."""
    rec = {"acc": float(lane["acc"])}
    for k in ("caught_byz", "evicted_honest"):
        if k in lane:
            rec[k] = int(lane[k])
    if "final_good" in lane:
        rec["final_good"] = lane["final_good"]
    traces = lane["traces"]
    if "zeta_sq" in traces:
        # measured heterogeneity alongside accuracy (DESIGN.md §13):
        # trial-mean honest dissimilarity, reported per cell
        rec["zeta_sq_mean"] = float(jnp.asarray(traces["zeta_sq"]).mean())
    if "escaped" in traces:
        # second-order lane (DESIGN.md §14): first step the escape
        # predicate fired (-1 = never), plus the final Rayleigh proxy
        rec["escape_step"] = sad_lib.first_escape_step(traces["escaped"])
        rec["min_eig_final"] = float(
            jnp.asarray(traces["min_eig_proxy"])[-1])
    # flight-recorder event log (DESIGN.md §15): the dense traces are
    # already host-side numpy here, so the pure-numpy extractor runs for
    # free; events are small and always stored with the record (traces
    # themselves stay opt-in via store_traces)
    host_traces = {k: np.asarray(v) for k, v in traces.items()}
    rec["events"] = ev_lib.events_to_json(ev_lib.extract_events(host_traces))
    rec["traces"] = traces
    return rec


def _split_lanes(out, n: int) -> List[Dict]:
    """(lane-stacked result pytree) -> per-lane host-side dicts."""
    host = jax.device_get(out)
    return [_lane_record(jax.tree.map(lambda x: x[i], host))
            for i in range(n)]


def run_group(group: Sequence[Scenario], *, batched: bool = True,
              tap=None, tap_every: int = 0) -> List[Dict]:
    """Run one batch-compatible scenario group -> per-scenario results.

    ``batched=False`` runs the same trial function one lane at a time
    (the unbatched oracle the vmap equivalence tests compare against).

    ``tap``/``tap_every`` enable the live heartbeat (DESIGN.md §17).
    The ``lane`` knob is added to the stack only when tapping, so the
    untapped program (and its committed tier-2 jaxpr baseline) is
    byte-for-byte unchanged.
    """
    rep = group[0]
    trial = make_trial_fn(rep, tap=tap, tap_every=tap_every)
    knobs = stack_knobs(group)
    if tap is not None and tap_every:
        knobs["lane"] = jnp.arange(len(group), dtype=jnp.int32)
    if batched:
        out = jax.jit(jax.vmap(trial))(knobs)
        jax.block_until_ready(out)
        return _split_lanes(out, len(group))
    fn = jax.jit(trial)
    lanes = []
    for i in range(len(group)):
        one = fn({k: v[i] for k, v in knobs.items()})
        jax.block_until_ready(one)
        lanes.append(_lane_record(jax.device_get(one)))
    return lanes


def run_scenarios(scenarios: Sequence[Scenario], *, batched: bool = True,
                  verbose: bool = False, collector=None,
                  tap_every: int = 0) -> Dict[str, Dict]:
    """Run a scenario list -> ``{scenario_id: result}``.

    Results carry ``acc`` (final eval accuracy), the safeguard diagnostics
    (``caught_byz`` / ``evicted_honest`` / ``final_good``) when the
    defense is stateful, ``traces`` (per-step metric stacks), and
    ``wall_s`` for the group the scenario ran in.

    ``collector`` (a ``repro.obs.live.LiveCollector``) with
    ``tap_every > 0`` streams per-window heartbeats from every running
    group; lane ids are rebound to the group's scenario ids before each
    launch (groups run sequentially, so the binding is race-free).
    """
    results: Dict[str, Dict] = {}
    tap = None
    for group in group_scenarios(scenarios):
        if collector is not None and tap_every:
            collector.set_lanes([cell_label(s) for s in group])
            tap = collector.tap
        t0 = time.time()
        lanes = run_group(group, batched=batched, tap=tap,
                          tap_every=tap_every)
        wall = time.time() - t0
        if verbose:
            fam, _ = attack_family(group[0])
            print(f"campaign-engine,{fam}/{group[0].defense},"
                  f"lanes={len(group)},wall_s={wall:.2f}")
        for s, rec in zip(group, lanes):
            rec = dict(rec)
            rec["wall_s"] = wall
            rec["group_lanes"] = len(group)
            results[scenario_id(s)] = rec
    return results
