"""Campaign CLI: declarative grids -> batched engine -> resumable store.

    PYTHONPATH=src python -m repro.campaign.run --campaign table1 --seeds 5

Built-in campaigns (all multi-seed; the engine turns seeds and compatible
knob axes into vmap lanes, see ``engine.batch_key``):

  table1           paper Table 1: attack x defense grid
  fig2             paper Fig 2(b): variance attack x periodic reset
  alpha_sweep      n_byz 0..4 (alpha 0..0.4) x {variance, sign_flip}
                   x {safeguard_double, coord_median}
  threshold_sweep  safeguard threshold_floor sweep under the variance
                   attack (single + double guard) — one program per
                   defense, every floor a vmap lane
  adaptive         feedback-coupled adversaries (DESIGN.md §11) x
                   {safeguard_double, mean} — the adapt_* controller
                   knobs are vmap lanes like seeds
  defense          the history-aware defense zoo (DESIGN.md §12) x
                   {variance, adaptive_flip} — clip/spectral knobs are
                   vmap lanes like seeds
  hetero           worker-heterogeneity subsystem (DESIGN.md §13):
                   Dirichlet label-skew alpha sweep x defense x attack
                   plus a teacher-rotation concept-shift block — the
                   hetero_alpha/hetero_shift knobs are vmap lanes
  saddle           saddle-escape verification testbed (DESIGN.md §14):
                   planted-saddle task x defense x attack, reporting
                   escape-step distributions — safeguard + sgd_escape
                   noise escapes within the theorem's budget while the
                   undefended mean under saddle_push provably stalls
                   (use --steps 400 for the separation; the
                   saddle_gap / noise_r / vr_period knobs are vmap lanes)
  live             live-monitoring demo grid (DESIGN.md §17): one clean
                   lane that must stay alert-free, the variance attack
                   vs the safeguard (eviction storm fires as the
                   colluders are caught) and vs the undefended mean
                   (no evictions — only the loss stream tells the story)
  smoke            2x2 mini-grid for CI / tests

A second invocation with the same arguments runs 0 new cells (the store
is keyed by scenario content hash); extending ``--seeds`` or a campaign's
axis lists only runs the delta.

``--tap-every K`` streams a typed heartbeat (``repro.obs.schema.TAP``)
every K steps from each running lane into ``<store>/live/<cell>.jsonl``
(``repro.obs.live``); ``--watch`` echoes each beat as a progress line as
it arrives.  Tail a running campaign from another terminal with

    PYTHONPATH=src python -m repro.obs.live tail --campaign live
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Callable, Dict, List

from repro.campaign import engine
from repro.campaign.scenario import (ADAPTIVE_ATTACKS, HETERO_DEFENSES,
                                     Scenario, TABLE1_ATTACKS,
                                     TABLE1_DEFENSES, ZOO_DEFENSES,
                                     expand_grid, scenario_id, with_seeds)
from repro.campaign.store import DEFAULT_ROOT, CampaignStore


def _table1(seeds: int, steps: int) -> List[Scenario]:
    grid = expand_grid(attack=list(TABLE1_ATTACKS),
                       defense=list(TABLE1_DEFENSES), steps=[steps])
    return with_seeds(grid, seeds)


def _fig2(seeds: int, steps: int) -> List[Scenario]:
    grid = expand_grid(attack=["variance"], defense=["safeguard_double"],
                       reset_period=[0, 40, 80], steps=[steps])
    return with_seeds(grid, seeds)


def _alpha_sweep(seeds: int, steps: int) -> List[Scenario]:
    grid = expand_grid(attack=["variance", "sign_flip"],
                       defense=["safeguard_double", "coord_median"],
                       n_byz=[0, 1, 2, 3, 4], steps=[steps])
    return with_seeds(grid, seeds)


def _threshold_sweep(seeds: int, steps: int) -> List[Scenario]:
    grid = expand_grid(attack=["variance"],
                       defense=["safeguard_single", "safeguard_double"],
                       threshold_floor=[0.05, 0.1, 0.3, 1.0, 3.0],
                       steps=[steps])
    return with_seeds(grid, seeds)


def _defense(seeds: int, steps: int) -> List[Scenario]:
    """The history-aware defense zoo (DESIGN.md §12) under the attack the
    paper says historyless defenses cannot survive (variance) and the
    strongest feedback-coupled adversary (adaptive_flip)."""
    grid = expand_grid(attack=["variance", "adaptive_flip"],
                       defense=list(ZOO_DEFENSES), steps=[steps])
    return with_seeds(grid, seeds)


def _adaptive(seeds: int, steps: int) -> List[Scenario]:
    """Feedback-coupled adversaries (DESIGN.md §11) against the safeguard
    and the no-defense baseline: the threshold tracker must degrade
    ``mean`` while SafeguardSGD stays within noise of its static rows."""
    grid = expand_grid(attack=list(ADAPTIVE_ATTACKS),
                       defense=["safeguard_double", "mean"], steps=[steps])
    return with_seeds(grid, seeds)


def _hetero(seeds: int, steps: int) -> List[Scenario]:
    """Worker-heterogeneity campaign (DESIGN.md §13): non-IID honest
    workers are where selection-style defenses (krum, trimmed_mean)
    falsely evict honest outliers and where bucketing repairs them.
    Dirichlet label-skew alpha sweep (every alpha a vmap lane) across
    the hetero defense suite under {no attack, variance, adaptive_flip},
    plus a teacher-rotation concept-shift block."""
    alphas = [0.05, 1.0, 10.0]
    attacks = ["none", "variance", "adaptive_flip"]
    no_sg = [d for d in HETERO_DEFENSES if d != "safeguard_double"]
    grid = expand_grid(hetero=["dirichlet"], hetero_alpha=alphas,
                       attack=attacks, defense=no_sg, steps=[steps])
    # the safeguard runs both its IID calibration (eviction multiplier
    # 1.5 — shows the concentration filter stressed by honest skew) and
    # the zeta-relaxed lane (2.0 — evicts nobody, still catches the
    # variance colluders); both scales are lanes of one program
    grid += expand_grid(hetero=["dirichlet"], hetero_alpha=alphas,
                        attack=attacks, defense=["safeguard_double"],
                        threshold_scale=[1.5, 2.0], steps=[steps])
    grid += expand_grid(hetero=["shift"], hetero_shift=[0.5, 1.5],
                        attack=["none", "variance"],
                        defense=["mean", "safeguard_double",
                                 "centered_clip"], steps=[steps])
    return with_seeds(grid, seeds)


def _saddle(seeds: int, steps: int) -> List[Scenario]:
    """Saddle-escape verification campaign (DESIGN.md §14): both planted
    task kinds x {theorem row, stall row}.  The theorem row is
    safeguard_double + sgd_escape perturbation (clean, attacked, and an
    SVRG-anchored lane); the stall row is the undefended mean under the
    curvature-aware saddle_push colluders (boost ramps against null
    feedback, so the iterate stays pinned at the saddle: escape_step
    stays -1).  Run with --steps 400 to see the separation; see
    ``escape_budget`` for the predicted bound."""
    base = dict(d_in=[16], lr=[0.1], batch=[40], noise_r=[0.05],
                saddle_gap=[0.5, 1.0], steps=[steps])
    grid: List[Scenario] = []
    for task in ("saddle_quad", "saddle_chain"):
        # theorem row: clean, SVRG-anchored, and attacked lanes (the
        # clean vr_period 0/8 cells are lanes of one program)
        grid += expand_grid(task=[task], defense=["safeguard_double"],
                            perturb=["sgd_escape"], escape_nu=[0.1],
                            attack=["none"], vr_period=[0, 8], **base)
        grid += expand_grid(task=[task], defense=["safeguard_double"],
                            perturb=["sgd_escape"], escape_nu=[0.1],
                            attack=["saddle_push"], adapt_init=[1.0],
                            **base)
        # stall row: undefended mean under the saddle-point attack
        grid += expand_grid(task=[task], defense=["mean"],
                            attack=["saddle_push"], adapt_init=[1.0],
                            **base)
    return with_seeds(grid, seeds)


def _live(seeds: int, steps: int) -> List[Scenario]:
    """Live-monitoring demo grid (DESIGN.md §17).  Three lanes: a clean
    safeguard run (the alert catalog must stay silent on it — the
    ``live-smoke`` CI gate asserts exactly that), the variance attack
    against the safeguard (the eviction-storm rule fires as the
    colluders are caught), and the same attack against the undefended
    mean (nothing is ever evicted; only the loss stream shows the
    damage)."""
    grid = expand_grid(attack=["none"], defense=["safeguard_double"],
                       steps=[steps])
    grid += expand_grid(attack=["variance"],
                        defense=["safeguard_double", "mean"],
                        steps=[steps])
    return with_seeds(grid, seeds)


def _smoke(seeds: int, steps: int) -> List[Scenario]:
    grid = expand_grid(attack=["sign_flip", "variance"],
                       defense=["safeguard_double", "coord_median"],
                       steps=[steps])
    return with_seeds(grid, seeds)


CAMPAIGNS: Dict[str, Callable[[int, int], List[Scenario]]] = {
    "table1": _table1,
    "fig2": _fig2,
    "alpha_sweep": _alpha_sweep,
    "threshold_sweep": _threshold_sweep,
    "adaptive": _adaptive,
    "defense": _defense,
    "hetero": _hetero,
    "saddle": _saddle,
    "live": _live,
    "smoke": _smoke,
}


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser(
        description="run a scenario campaign through the batched engine")
    ap.add_argument("--campaign", required=True, choices=sorted(CAMPAIGNS))
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--steps", type=int, default=None,
                    help="steps per trial (default 150; --quick default 40)")
    ap.add_argument("--quick", action="store_true",
                    help="short trials (40 steps unless --steps is given)")
    ap.add_argument("--root", default=DEFAULT_ROOT,
                    help="store root (experiments/campaigns)")
    ap.add_argument("--store-traces", action="store_true",
                    help="persist per-step metric traces as compressed "
                         ".npz sidecars under <store>/traces/ "
                         "(repro.obs.trace; event logs are always stored)")
    ap.add_argument("--loop", action="store_true",
                    help="run lanes unbatched (debugging / A-B timing)")
    ap.add_argument("--tap-every", type=int, default=0, metavar="K",
                    help="stream a live heartbeat every K steps per lane "
                         "into <store>/live/ (repro.obs.live; 0 = off)")
    ap.add_argument("--watch", action="store_true",
                    help="echo each heartbeat as a per-cell progress "
                         "line (implies --tap-every 50 if unset)")
    args = ap.parse_args(argv)

    steps = args.steps if args.steps is not None else (40 if args.quick
                                                       else 150)
    scenarios = CAMPAIGNS[args.campaign](args.seeds, steps)
    store = CampaignStore(args.campaign, root=args.root)
    pending = store.pending(scenarios)
    done = len(scenarios) - len(pending)
    print(f"campaign,{args.campaign},cells={len(scenarios)},done={done},"
          f"new_cells={len(pending)}")

    tap_every = args.tap_every or (50 if args.watch else 0)
    collector = None
    if tap_every:
        from repro.obs import live as live_lib

        # lazy file creation inside the collector keeps a resume run
        # (0 pending cells -> 0 heartbeats) byte-identical on disk
        collector = live_lib.LiveCollector(
            name=args.campaign,
            heartbeat_dir=os.path.join(store.dir, live_lib.LIVE_DIR),
            echo=((lambda line: print(f"live,{line}", flush=True))
                  if args.watch else None))

    t0 = time.time()
    if pending:
        n_groups = len(engine.group_scenarios(pending))
        print(f"campaign,{args.campaign},groups={n_groups}")
        results = engine.run_scenarios(pending, batched=not args.loop,
                                       verbose=True, collector=collector,
                                       tap_every=tap_every)
        for s in pending:
            rec = results[scenario_id(s)]
            store.append(s, rec, store_traces=args.store_traces)
            caught = rec.get("caught_byz", "-")
            zeta = rec.get("zeta_sq_mean")
            zeta = f",zeta_sq={zeta:.4g}" if zeta is not None else ""
            esc = rec.get("escape_step")
            esc = f",escape_step={esc}" if esc is not None else ""
            print(f"campaign,{args.campaign},{s.attack},{s.defense},"
                  f"seed={s.seed},acc={rec['acc']:.4f},caught={caught}"
                  f"{zeta}{esc}")
    wall = time.time() - t0
    if collector is not None:
        collector.close()
        print(f"campaign,{args.campaign},heartbeats={len(collector.ring)},"
              f"dropped={collector.dropped}")
    store.write_meta({"campaign": args.campaign, "seeds": args.seeds,
                      "steps": steps, "cells": len(scenarios),
                      "last_new_cells": len(pending),
                      "last_wall_s": round(wall, 2)})
    print(f"campaign,{args.campaign},ran={len(pending)},"
          f"wall_s={wall:.1f},store={store.path}")
    return {"cells": len(scenarios), "ran": len(pending), "wall_s": wall,
            "store": store.path}


if __name__ == "__main__":
    main()
