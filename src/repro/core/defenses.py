"""The unified stateful Defense protocol and the defense zoo.

The paper's central distinction — *historyless* aggregators fall to the
variance attack, windowed *history* survives it — used to be an
architectural split in this repo: ``core/aggregators.py`` was a bag of
pure functions, SafeguardSGD a bespoke stateful path with its own
``TrainState`` buffers, and the trainer/campaign special-cased each
(``needs_scores``, the ``safeguard_*`` name family).  This module is the
defense-side twin of the attack protocol (DESIGN.md §11): every defense
— historyless or not — is one frozen :class:`Defense` object

    init_state(grads_like)            -> state        [None = stateless]
    aggregate(state, grads, ctx)      -> (agg, state', info)

``grads`` is the worker-stacked gradient pytree (leaves ``(m, ...)``),
``ctx`` a dict of step-scoped resources the trainer provides (``rng``,
``scores`` from Zeno's held-batch oracle, ``acc_sharding`` for the flat
buffers).  ``info`` always carries ``good`` (the ``(m,)`` bool
membership mask this step aggregated over — all-True for non-filtering
defenses) and ``n_good``; filtering defenses additionally publish the
safeguard feedback keys (thresholds, distances to the concentration
median) that adaptive attacks observe (``attacks.defense_feedback``).

State is an ordinary pytree threaded through ``TrainState.defense_state``
— fixed shapes, no python branches — so whole trials stay
``lax.scan``-able and the campaign engine vmaps defense knobs
(``clip_tau``/``clip_beta``/``spectral_iters``, :data:`DEFENSE_DEFAULTS`)
exactly like the attack's ``adapt_*`` axes.

The zoo (:func:`make_registry`):

  * the seven historyless baselines (mean, coordinate median, trimmed
    mean, geometric medoid, Weiszfeld, Krum, Zeno) as trivially-stateless
    instances of the pure functions in ``core.aggregators``;
  * SafeguardSGD (single/double) — state IS the flat ``(m, d_pad)``
    accumulators of ``core.safeguard``;
  * ``centered_clip`` — centered clipping with per-worker server-side
    momentum [Karimireddy, He, Jaggi 2021; simplified convergence theory
    of Roberts & Smyth 2022]: history-aware, survives the variance
    attack without evicting anyone;
  * ``norm_filter`` — norm-threshold filtering against an EMA of the
    median reported norm (norm-thresholding defenses à la Sun et al.
    2019; the escape-saddle ByzantinePGD line of Yin et al. 2019 uses
    the same reject-by-magnitude primitive);
  * ``dnc`` — Divide-and-Conquer spectral filtering [Shejwalkar &
    Houmansadr 2021]: remove the ``n_byz`` workers with the largest
    projection onto the top singular direction of the centered gradient
    matrix, power iteration warm-started across steps;
  * ``safeguard_cclip`` — composition: the safeguard's windowed filter
    picks the good set, centered clipping aggregates over it;
  * ``bucketing_*`` — :func:`make_bucketing` [Karimireddy, He & Jaggi
    2022] as a *meta*-defense: per-step random s-bucket averaging in
    front of any wrapped aggregator (``bucketing_krum``,
    ``bucketing_cclip``), shrinking inter-worker heterogeneity by ~1/s
    before selection-style rules see the rows (DESIGN.md §13).

All stateful defenses operate on the flat ``(m, d_pad)`` buffer layout
of ``core.safeguard`` (one ``flatten_stacked`` per step), so the
pairwise-distance ones reuse the Pallas Gram kernel and the
``launch.sharding.flat_acc_pspec`` row sharding applies to their state
unchanged.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregators as agg_lib
from repro.core import safeguard as sg
from repro.core import tree_utils as tu

f32 = jnp.float32

# Knob defaults shared by the defense factories below AND the campaign
# layer's ``Scenario.clip_tau/clip_beta/spectral_iters`` fields — single
# source, so the legacy Trainer path and the campaign engine run the
# same defense under the same name (mirrors attacks.ADAPTIVE_DEFAULTS).
DEFENSE_DEFAULTS = {
    "clip_tau": 1.0,        # clip radius, relative to the median deviation
    "clip_beta": 0.9,       # worker-momentum EMA coefficient
    "spectral_iters": 4,    # DnC power-iteration steps per aggregation
    "bucket_s": 2,          # bucketing meta-defense: workers per bucket
    # empirical-filter eviction multiplier (paper Appendix C.1) — single
    # source is the SafeguardConfig field default.  1.5 is the paper's
    # IID calibration; under measured heterogeneity zeta the honest
    # spread-to-median ratio grows, so the hetero campaign runs a
    # relaxed lane (DESIGN.md §13)
    "threshold_scale": sg.SafeguardConfig.threshold_scale,
}

# Decouples the bucketing permutation stream from the safeguard's noise
# consumer of the same scan-threaded step rng (ctx["rng"]).
BUCKET_SALT = 0xB0C4

_CLIP_ITERS = 3             # fixed inner clipping iterations (static)
# Static power-iteration scan length; the `spectral_iters` knob masks the
# tail so traced and concrete values run the same program (bit-identity).
# A request above the cap would silently truncate — reject it loudly.
MAX_SPECTRAL_ITERS = 16


def derive_trim(n_byz: int, m: int):
    """Per-coordinate trim count for trimmed-mean at ``b = alpha * m`` —
    THE single source (previously repeated between
    ``aggregators.make_registry`` and the campaign layer).  Accepts a
    traced ``n_byz`` (returns a traced value; only defenses that consume
    ``n_byz`` dynamically may be called with one)."""
    cap = (m - 1) // 2
    if isinstance(n_byz, (int, np.integer)):
        return min(int(n_byz), cap)
    return jnp.minimum(n_byz, cap)


@dataclasses.dataclass(frozen=True)
class Defense:
    """One defense under the unified protocol.

    ``aggregate(state, grads, ctx) -> (agg, state', info)`` — ``grads``
    is the worker-stacked pytree *after* the Byzantine rewrite; ``info``
    always has ``good``/``n_good``.  ``init_state(grads_like) -> state``
    builds the carried pytree from a parameter-shaped pytree (``None``
    for the historyless baselines).

    ``static_nbyz``: the defense consumes ``n_byz`` as a python value
    (slice/selection bounds) — program structure for the campaign
    engine, a vmap knob otherwise.  ``flat_state``: the state rows are
    ``(m, d_pad)`` flat buffers shardable by
    ``launch.sharding.flat_acc_pspec``.
    """
    name: str
    aggregate: Callable
    init_state: Optional[Callable] = None
    needs_held_batch: bool = False    # Zeno's master-side score oracle
    static_nbyz: bool = False
    flat_state: bool = False

    @property
    def stateful(self) -> bool:
        return self.init_state is not None

    @property
    def historyless(self) -> bool:
        """The paper's dividing line: a defense with no carried state can
        only see one step of gradients — derived, so it cannot drift
        from ``stateful``."""
        return not self.stateful


def final_good(state) -> Optional[jax.Array]:
    """The last good/membership mask recorded in a defense state, or
    ``None`` when the defense does not track one (stateless baselines,
    pure clipping)."""
    if state is None:
        return None
    if hasattr(state, "good"):
        return state.good
    if isinstance(state, dict):
        if "good" in state:
            return state["good"]
        if "sg" in state:
            return state["sg"].good
    return None


def _all_good_info(m: int) -> Dict[str, jax.Array]:
    return {"good": jnp.ones((m,), bool), "n_good": jnp.asarray(m, f32)}


def _masked_info(keep: jax.Array) -> Dict[str, jax.Array]:
    return {"good": keep, "n_good": keep.sum().astype(f32)}


# --------------------------------------------------------------------------
# Historyless ports (the pure functions of core.aggregators)
# --------------------------------------------------------------------------

def _stateless(name: str, fn: Callable, *, needs_scores: bool = False,
               static_nbyz: bool = False) -> Defense:
    def aggregate(state, grads, ctx):
        m = tu.tree_worker_count(grads)
        if needs_scores:
            scores = (ctx or {}).get("scores")
            if scores is None:
                raise ValueError(f"{name} needs ctx['scores'] (a held-out "
                                 "batch at the trainer level)")
            agg = fn(grads, scores=scores)
        else:
            agg = fn(grads)
        return agg, state, _all_good_info(m)

    return Defense(name, aggregate, needs_held_batch=needs_scores,
                   static_nbyz=static_nbyz)


# --------------------------------------------------------------------------
# SafeguardSGD as a Defense
# --------------------------------------------------------------------------

def make_safeguard_defense(cfg: sg.SafeguardConfig,
                           name: Optional[str] = None) -> Defense:
    """The paper's defense under the protocol: the state is the plain
    :class:`core.safeguard.SafeguardState` (flat ``(m, d_pad)``
    accumulators by default)."""
    def init_state(grads_like):
        return sg.init_state(cfg, grads_like)

    def aggregate(state, grads, ctx):
        ctx = ctx or {}
        rng = ctx.get("rng") if cfg.nu > 0 else None
        new_state, agg, info = sg.safeguard_step(
            state, grads, cfg, rng, acc_sharding=ctx.get("acc_sharding"))
        return agg, new_state, info

    return Defense(name or f"safeguard_{cfg.mode}", aggregate,
                   init_state=init_state,
                   flat_state=(cfg.engine == "flat" and not cfg.use_sketch))


def from_aggregator(a: "agg_lib.Aggregator") -> Defense:
    """Back-compat shim: wrap a legacy ``aggregators.Aggregator``."""
    return _stateless(a.name, a.fn, needs_scores=a.needs_scores)


# --------------------------------------------------------------------------
# Flat-buffer helpers shared by the new stateful defenses
# --------------------------------------------------------------------------

def _layout_of(grads) -> sg.FlatLayout:
    """Layout from a *stacked* pytree (shape metadata only — trace-time)."""
    return sg.make_layout(jax.tree.map(lambda l: l[0], grads))


def _row_norms(mat: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.maximum((mat * mat).sum(axis=1), 0.0))


def _knob(x):
    """Coerce a defense knob to an *opaque* f32 scalar BEFORE any
    arithmetic.  Two effects, both needed for the engine-vs-Trainer
    bit-identity contract: the f32 cast stops a python-float knob from
    constant-folding in float64 (``1.0 - 0.9`` is one ulp off the f32
    subtraction), and the optimization barrier stops XLA from fusing a
    *literal* knob differently (fma choices change with literal
    coefficients at some shapes) than the campaign engine's traced vmap
    lane values.  A knob that is already a tracer is already opaque —
    and ``optimization_barrier`` has no batching rule, so the barrier
    only wraps the concrete (legacy Trainer) side."""
    x = jnp.asarray(x, f32)
    if isinstance(x, jax.core.Tracer):
        return x
    return jax.lax.optimization_barrier(x)


def _clip_rounds(v: jax.Array, center: jax.Array, tau, good=None):
    """``_CLIP_ITERS`` rounds of centered clipping: pull the center toward
    the (good-masked) mean of radius-clipped deviations.  ``tau`` is
    RELATIVE to the median deviation norm — scale-free across models,
    the practical radius rule of Karimireddy et al.'s experiments."""
    m = v.shape[0]
    tau = _knob(tau)
    w_mask = jnp.ones((m,), f32) if good is None else good.astype(f32)
    denom = jnp.maximum(w_mask.sum(), 1.0)
    c = center
    for _ in range(_CLIP_ITERS):
        delta = v - c[None, :]
        nrm = _row_norms(delta)
        tau_eff = tau * jnp.median(nrm)
        w = jnp.minimum(1.0, tau_eff / jnp.maximum(nrm, 1e-12)) * w_mask
        c = c + (delta * w[:, None]).sum(axis=0) / denom
    return c


def _maybe_shard(buf, ctx):
    sharding = (ctx or {}).get("acc_sharding")
    if sharding is not None:
        buf = jax.lax.with_sharding_constraint(buf, sharding)
    return buf


# --------------------------------------------------------------------------
# Centered clipping with worker momentum
# --------------------------------------------------------------------------

def make_centered_clip(m: int, tau=DEFENSE_DEFAULTS["clip_tau"],
                       beta=DEFENSE_DEFAULTS["clip_beta"]) -> Defense:
    """[Karimireddy, He, Jaggi 2021] Per-worker momentum ``v_i <-
    (1-beta) g_i + beta v_i`` followed by iterative centered clipping of
    the momenta around the previous aggregate.  History enters twice —
    the momentum buffers and the carried center — which is exactly what
    lets it survive the variance attack no historyless rule can
    (DESIGN.md §12); nobody is evicted, influence is *bounded* instead."""
    def init_state(grads_like):
        layout = sg.make_layout(grads_like)
        return {"momentum": jnp.zeros((m, layout.d_padded), f32),
                "center": jnp.zeros((layout.d_padded,), f32)}

    def aggregate(state, grads, ctx):
        layout = _layout_of(grads)
        gflat = sg.flatten_stacked(grads, layout)
        b = _knob(beta)
        v = (1.0 - b) * gflat + b * state["momentum"]
        v = _maybe_shard(v, ctx)
        c = _clip_rounds(v, state["center"], tau)
        agg = sg.unflatten_row(c, layout)
        info = _all_good_info(m)
        info["clip_center_norm"] = jnp.sqrt((c * c).sum())
        return agg, {"momentum": v, "center": c}, info

    return Defense("centered_clip", aggregate, init_state=init_state,
                   flat_state=True)


# --------------------------------------------------------------------------
# Norm-threshold filtering with an EMA norm estimate
# --------------------------------------------------------------------------

def make_norm_filter(m: int, mult: float = 2.0,
                     ema_beta: float = 0.9) -> Defense:
    """Reject-by-magnitude (the norm-clipping/thresholding baseline of
    Sun et al. 2019 and the ByzantinePGD line of Yin et al. 2019): keep
    workers whose reported norm is within ``mult`` times an EMA of the
    *median* reported norm, mean over the kept set.  The EMA is the
    history — a one-step norm spike (burst, sign-flip at scale) is
    rejected against the remembered honest scale, not against the
    current contaminated batch."""
    def init_state(grads_like):
        return {"ema": jnp.zeros((), f32), "t": jnp.zeros((), jnp.int32),
                "good": jnp.ones((m,), bool)}

    def aggregate(state, grads, ctx):
        nrm = jnp.sqrt(tu.tree_row_sq_norms(grads))
        med = jnp.median(nrm)
        eb = _knob(ema_beta)
        ema = jnp.where(state["t"] == 0, med,
                        eb * state["ema"] + (1.0 - eb) * med)
        keep = nrm <= _knob(mult) * jnp.maximum(ema, 1e-12)
        # never aggregate an empty set: the median-norm worker stays
        keep = keep | (jnp.arange(m) == jnp.argmin(jnp.abs(nrm - med)))
        agg = tu.tree_masked_mean(grads, keep)
        info = _masked_info(keep)
        info["norm_ema"] = ema
        new_state = {"ema": ema, "t": state["t"] + 1, "good": keep}
        return agg, new_state, info

    return Defense("norm_filter", aggregate, init_state=init_state)


# --------------------------------------------------------------------------
# DnC-style spectral filtering
# --------------------------------------------------------------------------

def make_dnc(m: int, n_byz,
             iters=DEFENSE_DEFAULTS["spectral_iters"]) -> Defense:
    """Divide-and-Conquer [Shejwalkar & Houmansadr 2021]: score each
    worker by its squared projection onto the top singular direction of
    the centered ``(m, d_pad)`` gradient matrix and drop the ``n_byz``
    largest.  The power iteration is warm-started from the previous
    step's direction (the state) — colluders drifting along a stable
    direction are found in very few iterations.  ``iters`` and
    ``n_byz`` may be traced (campaign vmap knobs): the iteration runs a
    static-length masked scan (:data:`MAX_SPECTRAL_ITERS`), the drop
    count selects a sorted-score threshold with ``jnp.take``."""
    if isinstance(iters, (int, np.integer)) and iters > MAX_SPECTRAL_ITERS:
        raise ValueError(
            f"spectral_iters={iters} exceeds MAX_SPECTRAL_ITERS="
            f"{MAX_SPECTRAL_ITERS} (the static scan length) and would "
            "silently truncate")

    def init_state(grads_like):
        layout = sg.make_layout(grads_like)
        v0 = jax.random.normal(jax.random.PRNGKey(0), (layout.d_padded,),
                               f32)
        return {"v": v0 / jnp.sqrt((v0 * v0).sum()),
                "good": jnp.ones((m,), bool)}

    def aggregate(state, grads, ctx):
        layout = _layout_of(grads)
        gflat = sg.flatten_stacked(grads, layout)
        centered = gflat - gflat.mean(axis=0, keepdims=True)

        def power_step(v, i):
            w = centered.T @ (centered @ v)          # O(m d) per iteration
            w = w / jnp.maximum(jnp.sqrt((w * w).sum()), 1e-12)
            return jnp.where(i < iters, w, v), None

        v, _ = jax.lax.scan(power_step, state["v"],
                            jnp.arange(MAX_SPECTRAL_ITERS))
        scores = (centered @ v) ** 2
        k = jnp.clip(jnp.asarray(n_byz, jnp.int32), 0, m - 1)
        thresh = jnp.take(jnp.sort(scores), m - 1 - k)
        keep = scores <= thresh
        agg = tu.tree_masked_mean(grads, keep)
        info = _masked_info(keep)
        info["spectral_scores"] = scores
        return agg, {"v": v, "good": keep}, info

    return Defense("dnc", aggregate, init_state=init_state,
                   flat_state=True)


# --------------------------------------------------------------------------
# Safeguard + centered clipping composition
# --------------------------------------------------------------------------

def make_safeguard_cclip(cfg: sg.SafeguardConfig,
                         tau=DEFENSE_DEFAULTS["clip_tau"],
                         beta=DEFENSE_DEFAULTS["clip_beta"]) -> Defense:
    """Composition: the safeguard's windowed filter decides *membership*
    (permanent eviction of drifting accumulators), centered clipping
    bounds the *per-step influence* of whoever remains — the two
    failure modes the components each leave open.  Publishes the full
    safeguard feedback (thresholds, distances), so adaptive attacks see
    the same public surface as against the plain safeguard."""
    if cfg.engine != "flat" or cfg.use_sketch:
        raise ValueError("safeguard_cclip requires the flat engine")

    def init_state(grads_like):
        sg_state = sg.init_state(cfg, grads_like)
        d_pad = sg_state.layout.d_padded
        return {"sg": sg_state,
                "momentum": jnp.zeros((cfg.m, d_pad), f32),
                "center": jnp.zeros((d_pad,), f32)}

    def aggregate(state, grads, ctx):
        ctx = ctx or {}
        rng = ctx.get("rng") if cfg.nu > 0 else None
        sg_state, _sg_agg, info = sg.safeguard_step(
            state["sg"], grads, cfg, rng,
            acc_sharding=ctx.get("acc_sharding"))
        layout = sg_state.layout
        gflat = sg.flatten_stacked(grads, layout)
        b = _knob(beta)
        v = (1.0 - b) * gflat + b * state["momentum"]
        v = _maybe_shard(v, ctx)
        c = _clip_rounds(v, state["center"], tau, good=info["good"])
        agg = sg.unflatten_row(c, layout)
        new_state = {"sg": sg_state, "momentum": v, "center": c}
        return agg, new_state, info

    return Defense("safeguard_cclip", aggregate, init_state=init_state,
                   flat_state=True)


# --------------------------------------------------------------------------
# Bucketing as a meta-defense
# --------------------------------------------------------------------------

def derive_bucket_nbyz(n_byz: int, s: int) -> int:
    """Byzantine budget for the *inner* aggregator after s-bucketing:
    each Byzantine worker contaminates at most one bucket, so at most
    ``ceil(b / s)`` bucket means are corrupt [Karimireddy, He & Jaggi
    2022, Lemma 1].  NOT capped — if the wrapped rule cannot tolerate
    this many corrupt inputs the combination is unsound, and the
    registry must omit it rather than silently understate the budget."""
    return -(-int(n_byz) // s)


def bucketing_krum_feasible(m: int, n_byz: int, s: int) -> bool:
    """Can inner Krum tolerate ``ceil(n_byz / s)`` corrupt bucket means
    on ``m / s`` buckets (Krum needs m > b + 2)?  THE single source for
    the registry's registration gate and the Scenario construction-time
    check — one recalibration site, no drift."""
    if s < 1 or m % s or m // s < 3:
        return False
    return derive_bucket_nbyz(n_byz, s) <= m // s - 3


def make_bucketing(inner: Defense, m: int, s: int,
                   name: Optional[str] = None) -> Defense:
    """[Karimireddy, He & Jaggi 2022] s-bucket random averaging before
    ANY wrapped aggregator: each step draws a fresh worker permutation
    from the scan-threaded rng (``ctx["rng"]``, salted), averages
    consecutive groups of ``s`` permuted workers into ``m/s`` bucket
    means, and hands those to the wrapped defense as if they were
    workers.  Averaging s random workers shrinks inter-"worker"
    heterogeneity by ~1/s, which is exactly what stops selection-style
    rules (Krum, medians) from locking onto one skewed shard under
    non-IID data (DESIGN.md §13) — while Byzantine influence stays
    bounded (a colluder corrupts at most its own bucket).

    The wrapped defense runs at ``m_inner = m / s``; its state (if any)
    is bucket-shaped and threads through unchanged.  Bucket-level
    ``good`` decisions are mapped back through the permutation to the
    ``(m,)`` worker surface the trainer and the adaptive attacks
    observe; bucket-level score/distance arrays are dropped (their
    worker axis is the wrong size for the feedback protocol), scalar
    diagnostics pass through.
    """
    if s < 1:
        raise ValueError(f"bucketing needs s >= 1, got {s}")
    if m % s:
        raise ValueError(f"bucketing: m={m} not divisible by bucket size "
                         f"s={s}")
    if inner.needs_held_batch:
        raise ValueError("bucketing cannot wrap a held-batch defense "
                         f"({inner.name}): its score oracle is per-worker, "
                         "not per-bucket")
    n_buckets = m // s

    def aggregate(state, grads, ctx):
        rng = (ctx or {}).get("rng")
        if rng is None:
            raise ValueError("bucketing needs ctx['rng'] (the "
                             "scan-threaded step rng)")
        perm = jax.random.permutation(jax.random.fold_in(rng, BUCKET_SALT),
                                      m)

        def bucketize(leaf):
            p = jnp.take(leaf, perm, axis=0)
            p = p.reshape((n_buckets, s) + leaf.shape[1:])
            return p.astype(f32).mean(axis=1).astype(leaf.dtype)

        buckets = jax.tree.map(bucketize, grads)
        agg, new_state, binfo = inner.aggregate(state, buckets, ctx)
        # bucket decision -> worker surface: a worker is good iff its
        # bucket survived this step's inner aggregation
        good = jnp.zeros((m,), bool).at[perm].set(
            jnp.repeat(binfo["good"], s))
        info = _masked_info(good)
        info["bucket_good"] = binfo["good"]
        for k, v in binfo.items():
            if k in ("good", "n_good") or k.startswith("threshold"):
                continue                       # wrong worker axis / surface
            if getattr(v, "ndim", None) == 0:
                info[k] = v
        return agg, new_state, info

    # flat_state stays False even for a flat-buffer inner: the inner
    # state has m/s rows, not the m worker rows the flat_acc_pspec
    # sharding contract promises (launch/specs would otherwise pin an
    # m-row spec onto a bucket-shaped buffer)
    return Defense(name or f"bucketing_{inner.name}", aggregate,
                   init_state=inner.init_state,
                   static_nbyz=inner.static_nbyz)


def _bucketing_static_nbyz_placeholder(name: str) -> Defense:
    """Registry slot for a bucketing-wrapped static-n_byz defense when the
    registry was built with a *traced* n_byz: the bucket Byzantine
    budget (``derive_bucket_nbyz``) is python slice structure, so such
    an entry can exist for name lookups but must never aggregate."""
    def aggregate(state, grads, ctx):
        raise ValueError(f"{name} consumes n_byz statically; build the "
                         "registry with a concrete n_byz to use it")
    return Defense(name, aggregate, static_nbyz=True)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

def make_registry(m: int, n_byz, *, T0: int = 20, T1: int = 120,
                  threshold_floor=0.1, reset_period: int = 0,
                  use_sketch: bool = False,
                  clip_tau=DEFENSE_DEFAULTS["clip_tau"],
                  clip_beta=DEFENSE_DEFAULTS["clip_beta"],
                  spectral_iters=DEFENSE_DEFAULTS["spectral_iters"],
                  bucket_s: int = DEFENSE_DEFAULTS["bucket_s"],
                  threshold_scale=DEFENSE_DEFAULTS["threshold_scale"],
                  norm_mult: float = 2.0,
                  norm_ema_beta: float = 0.9) -> Dict[str, Defense]:
    """Every defense, parameterized the way the paper's protocol runs
    them (``b = alpha * m``; safeguard windows/thresholds as given).

    ``threshold_floor``, ``clip_tau``, ``clip_beta``, ``spectral_iters``
    and — for the non-``static_nbyz`` entries — ``n_byz`` may be traced
    scalars (campaign vmap knobs): registry construction never calls a
    defense, and the knobs only feed arithmetic inside ``aggregate``.
    """
    trim = derive_trim(n_byz, m)

    def sg_cfg(mode):
        return sg.SafeguardConfig(m=m, T0=T0, T1=T1, mode=mode,
                                  threshold_floor=threshold_floor,
                                  threshold_scale=threshold_scale,
                                  reset_period=reset_period,
                                  use_sketch=use_sketch)

    reg = {
        "mean": _stateless("mean", agg_lib.mean),
        "coord_median": _stateless("coord_median",
                                   agg_lib.coordinate_median),
        "trimmed_mean": _stateless(
            "trimmed_mean",
            functools.partial(agg_lib.trimmed_mean, trim=trim),
            static_nbyz=True),
        "geo_median": _stateless("geo_median", agg_lib.geometric_medoid),
        "weiszfeld": _stateless("weiszfeld", agg_lib.geometric_median),
        "krum": _stateless(
            "krum", functools.partial(agg_lib.krum, n_byz=n_byz),
            static_nbyz=True),
        "zeno": _stateless(
            "zeno", functools.partial(agg_lib.zeno, n_byz=n_byz),
            needs_scores=True, static_nbyz=True),
        "safeguard_single": make_safeguard_defense(sg_cfg("single"),
                                                   "safeguard_single"),
        "safeguard_double": make_safeguard_defense(sg_cfg("double"),
                                                   "safeguard_double"),
        "centered_clip": make_centered_clip(m, tau=clip_tau,
                                            beta=clip_beta),
        "norm_filter": make_norm_filter(m, mult=norm_mult,
                                        ema_beta=norm_ema_beta),
        "dnc": make_dnc(m, n_byz, iters=spectral_iters),
    }
    if not use_sketch:
        # the composition needs the flat accumulators (its momentum shares
        # their layout) — a sketched registry simply omits it rather than
        # refusing to build the twelve defenses that work fine
        reg["safeguard_cclip"] = make_safeguard_cclip(sg_cfg("double"),
                                                      tau=clip_tau,
                                                      beta=clip_beta)
    # bucketing meta-defense (DESIGN.md §13): registered whenever the
    # bucket shapes work out (m divisible, enough buckets for the inner
    # rule); an incompatible population simply omits the entries, like
    # the sketched registry omits safeguard_cclip
    if bucket_s >= 1 and m % bucket_s == 0 and (m // bucket_s) >= 3:
        nb = m // bucket_s
        if not isinstance(n_byz, (int, np.integer)):
            # traced n_byz (a campaign knob for some OTHER defense in the
            # same registry build): keep the name resolvable, refuse use
            reg["bucketing_krum"] = _bucketing_static_nbyz_placeholder(
                "bucketing_krum")
        elif bucketing_krum_feasible(m, n_byz, bucket_s):
            # only register when inner Krum can actually tolerate
            # ceil(b/s) corrupt bucket means — an unsound combination is
            # omitted, never silently weakened
            inner_krum = _stateless(
                "krum", functools.partial(
                    agg_lib.krum,
                    n_byz=derive_bucket_nbyz(n_byz, bucket_s)),
                static_nbyz=True)
            reg["bucketing_krum"] = make_bucketing(inner_krum, m, bucket_s)
        reg["bucketing_cclip"] = make_bucketing(
            make_centered_clip(nb, tau=clip_tau, beta=clip_beta),
            m, bucket_s, name="bucketing_cclip")
    return reg


def static_nbyz_names() -> frozenset:
    """Defense names that consume ``n_byz`` as program structure — the
    campaign engine keys its batch groups on this (single source; the
    frozenset previously hard-coded in ``campaign.engine``).  The probe
    population (m=8, b=1) is the smallest where every registry entry —
    including bucketing_krum's feasibility gate — registers."""
    return frozenset(name for name, d in make_registry(8, 1).items()
                     if d.static_nbyz)
