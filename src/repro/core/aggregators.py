"""Baseline robust aggregators the paper compares against (Section 5 /
Appendix C.1): naive mean, coordinate-wise median, trimmed mean, geometric
median (both the paper's medoid form and Weiszfeld), Krum, and Zeno.

All aggregators are *historyless*: they map the ``m`` gradients of the
current step to one aggregate and know nothing about previous steps — the
property the variance attack [Baruch et al. 2019] exploits and the
safeguard's windowed accumulators fix.

Interface: stacked pytree (leaves ``(m, ...)``) -> parameter pytree.
These pure functions are the numerics oracles; the trainer/campaign
consume them as stateless instances of the unified Defense protocol
(``core.defenses``, DESIGN.md §12).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import tree_utils as tu


def mean(grads):
    """Naive mean — no Byzantine tolerance at all."""
    return jax.tree.map(lambda g: g.mean(axis=0), grads)


def coordinate_median(grads):
    """Definition C.2 — per-coordinate median over workers."""
    def one(g):
        return jnp.median(g.astype(jnp.float32), axis=0).astype(g.dtype)
    return jax.tree.map(one, grads)


def trimmed_mean(grads, trim: int):
    """Drop the ``trim`` lowest and highest values per coordinate, then mean
    (Yin et al. 2018)."""
    def one(g):
        m = g.shape[0]
        if 2 * trim >= m:
            raise ValueError(f"trim {trim} too large for m={m}")
        s = jnp.sort(g.astype(jnp.float32), axis=0)
        kept = s[trim:m - trim]
        return kept.mean(axis=0).astype(g.dtype)
    return jax.tree.map(one, grads)


def geometric_medoid(grads):
    """Paper Definition C.1 as implemented in their experiments: the set
    element minimizing the summed distance to all others."""
    sqdist = tu.tree_pairwise_sqdist(grads)
    scores = jnp.sqrt(sqdist).sum(axis=1)
    return tu.tree_select_worker(grads, jnp.argmin(scores))


def geometric_median(grads, iters: int = 8, eps: float = 1e-8):
    """True geometric median via Weiszfeld iterations (smoothed).

    The iterate is carried in f32 across ALL scan steps and cast to the
    gradient dtype exactly once at the end — a per-step round trip
    through bf16/f16 grads would re-quantize the fixed point every
    iteration and stall convergence at the low-precision grid.  The
    weights guard against ``w.sum() == 0`` (every distance overflowing
    to inf for huge-magnitude inputs makes every weight 0, and ``w /
    w.sum()`` would turn the whole iterate into NaN).
    """
    m = tu.tree_worker_count(grads)
    grads32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    y0 = jax.tree.map(lambda g: g.mean(axis=0), grads32)

    def body(y, _):
        # distances ||g_i - y||
        def dist_sq_leaf(g, c):
            d = g - c[None]
            return (d * d).reshape(m, -1).sum(axis=1)
        parts = jax.tree.map(dist_sq_leaf, grads32, y)
        dist = jnp.sqrt(sum(jax.tree_util.tree_leaves(parts)) + eps)
        w = 1.0 / dist
        w = w / jnp.maximum(w.sum(), jnp.float32(1e-30))
        y_new = jax.tree.map(lambda g: jnp.tensordot(w, g, axes=1), grads32)
        return y_new, None

    y, _ = jax.lax.scan(body, y0, None, length=iters)
    return jax.tree.map(lambda yl, g: yl.astype(g.dtype), y, grads)


def krum(grads, n_byz: int):
    """Definition C.3 — select the worker whose m - b - 2 nearest
    neighbours are closest in squared distance."""
    m = tu.tree_worker_count(grads)
    k = m - n_byz - 2
    if k < 1:
        raise ValueError(f"Krum needs m > b + 2 (m={m}, b={n_byz})")
    sqdist = tu.tree_pairwise_sqdist(grads)
    sqdist = sqdist.at[jnp.arange(m), jnp.arange(m)].set(jnp.inf)
    nearest = jnp.sort(sqdist, axis=1)[:, :k]
    scores = nearest.sum(axis=1)
    return tu.tree_select_worker(grads, jnp.argmin(scores))


def zeno(grads, scores: jax.Array, n_byz: int):
    """Definition C.4 — mean of the ``m - b`` gradients with the highest
    *stochastic descendant scores* (computed by the caller: Zeno needs a
    master-side loss oracle, see ``train.trainer.zeno_scores``)."""
    m = tu.tree_worker_count(grads)
    keep = m - n_byz
    order = jnp.argsort(-scores)              # descending
    mask = jnp.zeros((m,), bool).at[order[:keep]].set(True)
    return tu.tree_masked_mean(grads, mask)


def zeno_score(loss_before: jax.Array, loss_after: jax.Array,
               grad_sq_norm: jax.Array, rho: float = 5e-4) -> jax.Array:
    """Score(u) = f_r(x) - f_r(x - eta u) - rho ||u||^2 (eta folded in by
    the caller evaluating ``loss_after`` at ``x - eta u``)."""
    return loss_before - loss_after - rho * grad_sq_norm


# --------------------------------------------------------------------------
# Legacy registry (kept for back-compat; the unified protocol registry
# lives in core.defenses.make_registry)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Aggregator:
    name: str
    fn: Callable                 # (grads, **ctx) -> aggregate
    needs_scores: bool = False   # Zeno
    historyless: bool = True


def make_registry(n_byz: int, m: int):
    """Aggregators parameterized the way the paper runs them (b = alpha*m)."""
    from repro.core.defenses import derive_trim   # single trim source
    trim = derive_trim(n_byz, m)
    return {
        "mean": Aggregator("mean", mean),
        "coord_median": Aggregator("coord_median", coordinate_median),
        "trimmed_mean": Aggregator(
            "trimmed_mean", functools.partial(trimmed_mean, trim=trim)),
        "geo_median": Aggregator("geo_median", geometric_medoid),
        "weiszfeld": Aggregator("weiszfeld", geometric_median),
        "krum": Aggregator("krum", functools.partial(krum, n_byz=n_byz)),
        "zeno": Aggregator(
            "zeno", functools.partial(zeno, n_byz=n_byz), needs_scores=True),
    }
