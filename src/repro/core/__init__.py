"""Core library: SafeguardSGD (the paper's contribution), baseline robust
aggregators, and the Byzantine attack suite."""

from repro.core.safeguard import (    # noqa: F401
    SafeguardConfig, SafeguardState, init_state, safeguard_step)
from repro.core import aggregators    # noqa: F401
from repro.core import attacks        # noqa: F401
from repro.core import defenses       # noqa: F401
from repro.core import tree_utils     # noqa: F401
from repro.core import sketch         # noqa: F401
from repro.core.defenses import Defense  # noqa: F401
