"""CountSketch (sparse Johnson-Lindenstrauss) projection of gradients.

Beyond-paper optimization ("sketched safeguard", DESIGN.md §3): the paper's
filter only consumes *pairwise distances* between per-worker gradient
accumulators.  A CountSketch ``S: R^d -> R^k`` preserves inner products in
expectation with variance ``O(||x||^2 ||y||^2 / k)``; concatenating ``r``
independent sketches scaled by ``1/sqrt(r)`` reduces the variance by ``r``.
Accumulating sketches instead of full gradients drops the safeguard state
from ``O(m * d)`` to ``O(m * r * k)`` and removes the large accumulate /
Gram traffic entirely.

The sketch state is already a flat ``(m, r*k)`` matrix — the sketched
safeguard is the degenerate (lossy) endpoint of the flat-buffer engine of
``core.safeguard`` (DESIGN.md §6); it carries no :class:`FlatLayout`
because rows are not unflattenable.

The hash functions are multiply-mod hashes over the flat coordinate index,
seeded per (leaf, repetition) so the projection is a fixed deterministic
linear map — exactly what the JL argument requires.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Large odd multipliers for the multiply-mod hash family.
_PRIMES = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F,
           0x165667B1, 0xD3A2646D, 0xFD7046C5, 0xB55A4F09)


def _hash_idx(n: int, seed: int, rep: int, k: int):
    """Bucket index and sign for each of ``n`` flat coordinates."""
    i = jax.lax.iota(jnp.uint32, n)
    a = jnp.uint32(_PRIMES[rep % len(_PRIMES)])
    b = jnp.uint32((seed * 2654435761 + rep * 40503 + 12345) % (1 << 32))
    h = i * a + b
    # high bits are better mixed than low bits for multiply-mod hashes
    bucket = ((h >> jnp.uint32(8)) % jnp.uint32(k)).astype(jnp.int32)
    sign = jnp.where((h >> jnp.uint32(7)) & jnp.uint32(1), 1.0, -1.0)
    return bucket, sign.astype(jnp.float32)


def _linear_index(shape) -> "jax.Array":
    """Row-major linear index of every element of ``shape`` (uint32),
    built from broadcasted iotas — elementwise, so it inherits whatever
    sharding the leaf has (a flattening reshape would gather the leaf)."""
    idx = jnp.zeros(shape, jnp.uint32)
    stride = 1
    for axis in reversed(range(len(shape))):
        idx = idx + jax.lax.broadcasted_iota(
            jnp.uint32, shape, axis) * jnp.uint32(stride)
        stride *= shape[axis]
    return idx


def _hash_of(idx, seed: int, rep: int, k: int):
    a = jnp.uint32(_PRIMES[rep % len(_PRIMES)])
    b = jnp.uint32((seed * 2654435761 + rep * 40503 + 12345) % (1 << 32))
    h = idx * a + b
    bucket = ((h >> jnp.uint32(8)) % jnp.uint32(k)).astype(jnp.int32)
    sign = jnp.where((h >> jnp.uint32(7)) & jnp.uint32(1), 1.0, -1.0)
    return bucket, sign.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("k", "reps", "seed"))
def sketch_tree(tree, *, k: int = 2048, reps: int = 4, seed: int = 0):
    """Project a stacked pytree ``(m, ...)`` to sketches ``(m, reps * k)``.

    Implemented as an elementwise hash + multi-dim scatter-add per leaf —
    never a ``reshape(m, -1)``, which would destroy the model-axis
    sharding of large leaves and all-gather them (measured: 7.3 TiB/device
    on deepseek-v2; see EXPERIMENTS.md §Perf)."""
    leaves = jax.tree_util.tree_leaves(tree)
    m = leaves[0].shape[0]
    out = jnp.zeros((m, reps * k), dtype=jnp.float32)
    for li, leaf in enumerate(leaves):
        body = leaf.shape[1:] if leaf.ndim > 1 else (1,)
        lf = leaf.astype(jnp.float32).reshape((m,) + body) \
            if leaf.ndim == 1 else leaf.astype(jnp.float32)
        idx = _linear_index(body)
        for r in range(reps):
            bucket, sign = _hash_of(idx, seed * 1000003 + li, r, k)
            signed = lf * sign[None]
            # scatter-add over all body axes into k buckets, per worker
            out = out.at[:, r * k:(r + 1) * k].add(
                jnp.zeros((m, k), jnp.float32).at[:, bucket].add(signed))
    return out / jnp.sqrt(jnp.float32(reps))


def sketch_pairwise_sqdist(sketches: jax.Array) -> jax.Array:
    """Pairwise squared distances between sketch rows ``(m, rk)``."""
    gram = sketches @ sketches.T
    diag = jnp.diagonal(gram)
    return jnp.maximum(diag[:, None] + diag[None, :] - 2.0 * gram, 0.0)
