"""Pytree utilities for stacked per-worker gradients.

Throughout the core library, per-worker gradients are represented as a
"stacked pytree": a pytree with the same structure as the model parameters
whose every leaf carries a leading worker axis ``m``.  All pairwise geometry
(the safeguard filter, Krum, the geometric median) is derived from the
``m x m`` Gram matrix, which is computed leaf-by-leaf so that nothing of
size ``O(m * d)`` is ever materialized on a single device: under a sharded
``jit``, each leaf contributes a *partial* Gram from its local shard and XLA
inserts a tiny ``(m, m)`` all-reduce.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_worker_count(tree) -> int:
    """Leading-axis size shared by every leaf of a stacked pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        raise ValueError("empty pytree")
    m = leaves[0].shape[0]
    for leaf in leaves:
        if leaf.shape[0] != m:
            raise ValueError(
                f"inconsistent worker axis: {leaf.shape[0]} vs {m}")
    return m


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree, c):
    return jax.tree.map(lambda x: x * c, tree)


def tree_where_reset(tree, reset: jax.Array):
    """Zero every leaf when ``reset`` (scalar bool) is set."""
    return jax.tree.map(lambda x: jnp.where(reset, jnp.zeros_like(x), x), tree)


def tree_gram(tree, *, stream_min: int = 8) -> jax.Array:
    """``(m, m)`` Gram matrix  G[i, j] = <g_i, g_j>  of a stacked pytree.

    Computed leaf-wise with a multi-contracting-dim ``dot_general`` (NOT a
    reshape-to-matrix, which would break the sharding of the model axes).
    The cross-worker products still require combining all workers' values
    of each coordinate; under a (worker -> data)-sharded jit XLA realizes
    this as an all-gather of the worker axis — O(m * d_local) live bytes
    if done at once.  For stacked-layer leaves (ndim >= 3 with a
    layer-stack axis of length >= ``stream_min``) we therefore *stream*
    the contraction with a ``lax.scan`` over the stack axis: peak memory
    drops to O(m * d_local / n_layers) while total FLOPs/collective bytes
    are unchanged (EXPERIMENTS.md §Perf, deepseek-v2 hillclimb).
    """
    leaves = jax.tree_util.tree_leaves(tree)
    m = leaves[0].shape[0]
    gram = jnp.zeros((m, m), dtype=jnp.float32)
    for leaf in leaves:
        lf = leaf.astype(jnp.float32)
        if lf.ndim == 1:                       # scalar-per-worker leaf
            lf = lf[:, None]
        if lf.ndim >= 3 and lf.shape[1] >= stream_min:
            sl = jnp.moveaxis(lf, 1, 0)        # (stack, m, ...)
            contract = tuple(range(1, lf.ndim - 1))

            def gstep(acc, chunk):
                acc = acc + jax.lax.dot_general(
                    chunk, chunk, ((contract, contract), ((), ())),
                    preferred_element_type=jnp.float32)
                return acc, None

            part, _ = jax.lax.scan(gstep, jnp.zeros((m, m), jnp.float32),
                                   sl)
            gram = gram + part
        else:
            contract = tuple(range(1, lf.ndim))
            gram = gram + jax.lax.dot_general(
                lf, lf, ((contract, contract), ((), ())),
                preferred_element_type=jnp.float32)
    return gram


def tree_dot(a, b) -> jax.Array:
    """Scalar <a, b> over full (non-stacked) pytrees.

    Elementwise multiply + full reduction — NOT ``vdot``, whose flattening
    reshape breaks the sharding of multi-axis leaves and forces XLA to
    gather the full tensor (hundreds of GB for MoE expert grads).
    """
    parts = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)),
        a, b)
    return jnp.asarray(sum(jax.tree_util.tree_leaves(parts)))


def tree_sq_norm(tree) -> jax.Array:
    return tree_dot(tree, tree)


def tree_row_sq_norms(tree) -> jax.Array:
    """``(m,)`` squared L2 norm of every worker row of a stacked pytree —
    the Gram diagonal at O(m d) instead of the O(m^2 d) full Gram.
    Elementwise square + per-leaf reduction (no flattening reshape), so
    model-axis sharding of large leaves survives."""
    leaves = jax.tree_util.tree_leaves(tree)
    m = leaves[0].shape[0]
    tot = jnp.zeros((m,), jnp.float32)
    for leaf in leaves:
        lf = leaf.astype(jnp.float32)
        sq = lf * lf
        if lf.ndim > 1:
            sq = sq.sum(axis=tuple(range(1, lf.ndim)))
        tot = tot + sq
    return tot


def gram_to_sqdist(gram: jax.Array) -> jax.Array:
    """Pairwise squared distances from a Gram matrix, clipped at 0."""
    diag = jnp.diagonal(gram)
    sq = diag[:, None] + diag[None, :] - 2.0 * gram
    return jnp.maximum(sq, 0.0)


def tree_pairwise_sqdist(tree) -> jax.Array:
    """``(m, m)`` pairwise squared L2 distances between workers."""
    return gram_to_sqdist(tree_gram(tree))


def tree_masked_mean(tree, mask: jax.Array):
    """Mean over workers ``i`` with ``mask[i]``; mask is float/bool (m,)."""
    w = mask.astype(jnp.float32)
    denom = jnp.maximum(w.sum(), 1.0)

    def one(leaf):
        wshape = (-1,) + (1,) * (leaf.ndim - 1)
        s = (leaf.astype(jnp.float32) * w.reshape(wshape)).sum(axis=0)
        return (s / denom).astype(leaf.dtype)

    return jax.tree.map(one, tree)


def tree_dissimilarity(tree, mask: jax.Array) -> jax.Array:
    """Mean squared distance of the masked workers' rows to their own
    mean: ``E_{i in mask} ||g_i - g_bar_mask||^2`` — the measured
    zeta^2 heterogeneity of the non-IID assumption (DESIGN.md §13).
    O(m d): one masked mean, one row-norm pass, no Gram."""
    w = mask.astype(jnp.float32)
    center = tree_masked_mean(tree, mask)
    diffs = jax.tree.map(
        lambda g, c: g.astype(jnp.float32) - c[None].astype(jnp.float32),
        tree, center)
    sq = tree_row_sq_norms(diffs)
    return (sq * w).sum() / jnp.maximum(w.sum(), 1.0)


def tree_stack_flatten(tree):
    """Stacked pytree -> dense ``(m, d)`` matrix (small models only)."""
    leaves = jax.tree_util.tree_leaves(tree)
    m = leaves[0].shape[0]
    return jnp.concatenate([l.reshape(m, -1) for l in leaves], axis=1)


def tree_unflatten_like(flat_row: jax.Array, like):
    """Inverse of :func:`tree_stack_flatten` for a single row (d,)."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out, off = [], 0
    for leaf in leaves:
        size = leaf.size
        out.append(flat_row[off:off + size].reshape(leaf.shape).astype(leaf.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_select_worker(tree, idx):
    """Pick worker ``idx`` (traced scalar ok) out of a stacked pytree."""
    return jax.tree.map(lambda l: jnp.take(l, idx, axis=0), tree)


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)
