"""Byzantine attack library (paper Section 5 + Appendix C) and the
feedback-coupled adversary protocol (DESIGN.md §11).

An attack is a stateful ``observe / act`` object (:class:`Attack`):

    act(grads, byz_mask, state, step, rng) -> (grads', state')
    observe(state, feedback, byz_mask)     -> state'          [optional]

``act`` rewrites the rows of the stacked honest per-worker gradients that
``byz_mask`` (a static (m,) bool array) marks as Byzantine; honest rows
pass through untouched.  ``observe`` — present only on *adaptive* attacks
— folds the defense's **public outputs of the previous step** into the
attack state: the good mask, the live eviction thresholds, each worker's
distance to the concentration median, and the filter scores (see
:func:`feedback_from_info`).  This is the strongest threat model the
paper permits: Remark 2.2 allows Byzantine vectors to depend on
*everything* up to the current step, including the defense's decisions.
The trainer threads the state through ``TrainState.attack_state``, so the
whole loop stays ``lax.scan``-able and vmap-able (campaign engine).

Open-loop attacks (pure functions of the current honest stack; attacks
may collude — they see the full honest gradients):

  * ``none``              — honest execution;
  * ``sign_flip``         — send the negated gradient;
  * ``scaled_flip``       — send ``-scale * g`` (the paper's *safeguard
    attack*, scale 0.6 / 0.7, an inner-product-manipulation instance);
  * ``delayed``           — send the gradient from ``D`` steps ago
    (implemented with a circular buffer of the honest mean gradient);
  * ``variance``          — [Baruch et al. 2019] collusive attack: every
    Byzantine worker reports ``mu - z * sigma`` per coordinate, the largest
    mean shift statistically indistinguishable within one step;
  * ``ipm``               — inner-product manipulation [Xie et al. 2020]:
    report ``-eps * mean(honest)``;
  * ``burst``             — Appendix C.3 attack on the convex algorithm of
    Alistarh et al. 2018: behave honestly except for a contiguous window of
    steps in which the gradient is scaled by ``-burst_scale``;
  * ``random_noise``      — i.i.d. Gaussian junk (sanity baseline).

Feedback-coupled (adaptive) attacks:

  * ``adaptive_flip``     — threshold-tracking scaled flip: a multiplicative
    controller ramps the flip scale while the colluders' accumulated
    distance sits below ``target`` of the live eviction threshold, eases
    off as it approaches, and backs off hard when a colluder is caught;
  * ``adaptive_variance`` — eviction-aware [Baruch et al.]: shrinks ``z``
    whenever a colluder is newly evicted, creeps back up otherwise;
  * ``oscillating``       — hysteresis attacker: flips gradients until the
    tracked distance crosses a high-water fraction of the threshold, then
    behaves honestly (freezing the deviation until the window reset drains
    it) and resumes below the low-water mark;
  * ``median_capture``    — greedy collusion on the concentration median:
    all colluders report ``(1 - eps) * mean(honest)`` (intra-cluster
    distance 0, hugging the honest cluster) and ramp ``eps`` greedily
    while one of them *holds* the median — trying to drag the reference
    point and push honest workers over the threshold — retreating toward
    the honest mean whenever the median is lost or a colluder is caught;
  * ``saddle_push``       — the saddle-point attack of Yin et al.
    (arXiv:1806.05358) on the planted-saddle testbed (DESIGN.md §14):
    colluders know the planted negative-curvature subspace, mimic the
    honest mean off it, and on it report the cancellation
    ``-(n_h/n_b) * boost * P_esc(mean honest)`` so the aggregate's
    escape component becomes ``(1 - boost)`` of honest — ``boost > 1``
    actively pushes the iterate back toward the saddle.  Near the
    saddle honest gradients are tiny, so the cancellation is almost
    free; as the iterate starts to escape the cost grows and the
    safeguard's windowed accumulators expose it.  The same controller
    as ``adaptive_flip`` is the honest-mimicry budget: ``boost`` ramps
    while the colluders' accumulated distance has headroom and retreats
    when the live threshold leaves none (task-coupled: built by the
    campaign engine with the task's planted directions, not part of
    :func:`make_registry`).

Label-flipping is a *data* attack, implemented in ``repro.data`` (the
Byzantine worker computes a true gradient of a corrupted loss).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import tree_utils as tu

f32 = jnp.float32

# Threshold reported to adaptive attacks when no filtering defense is
# active (null feedback): effectively infinite headroom, so trackers ramp
# to their cap.  Finite (not inf) so ratio arithmetic stays NaN-free.
OPEN_LOOP_THRESHOLD = 1e30

# Collusion strength of the static variance attack: ``mu - z * sigma``
# per coordinate.  z = 1.5 keeps every Byzantine coordinate well inside
# the 3-sigma population envelope (statistical plausibility within one
# step) while actually producing the paper's Table-1 picture at the
# CPU protocol scale — historyless baselines degrade measurably, the
# safeguard's windowed accumulators catch the drift.  It is the same
# cap the eviction-aware ``adaptive_variance`` ramps toward (z_max).
VARIANCE_Z = 1.5

# Controller defaults shared by the adaptive-attack factories below AND
# the campaign layer's ``Scenario.adapt_*`` fields — single source, so
# the legacy Trainer path (registry defaults) and the campaign engine
# (Scenario knobs) run the same attack under the same name.
ADAPTIVE_DEFAULTS = {
    "adapt_init": 0.2,     # initial scale / z / eps
    "adapt_rate": 1.08,    # multiplicative ramp while there is headroom
    "adapt_down": 0.5,     # back-off on a fresh eviction
    "adapt_target": 0.8,   # threshold fraction the tracker aims at
}


def _mix(honest, adversarial, byz_mask):
    """Per-worker select: byzantine rows from ``adversarial``."""
    def one(h, a):
        mshape = (-1,) + (1,) * (h.ndim - 1)
        return jnp.where(byz_mask.reshape(mshape), a.astype(h.dtype), h)
    return jax.tree.map(one, honest, adversarial)


def _honest_stats(grads, byz_mask):
    """Mean and std over honest workers only, per coordinate."""
    w = (~byz_mask).astype(f32)
    n = jnp.maximum(w.sum(), 1.0)

    def stats(g):
        gw = g.astype(f32)
        wshape = (-1,) + (1,) * (g.ndim - 1)
        mu = (gw * w.reshape(wshape)).sum(axis=0) / n
        var = (((gw - mu[None]) ** 2) * w.reshape(wshape)).sum(axis=0) / n
        return mu, jnp.sqrt(var + 1e-12)
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    out = [stats(l) for l in leaves]
    mu_tree = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    sd_tree = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return mu_tree, sd_tree


# --------------------------------------------------------------------------
# Defense feedback (the public outputs adaptive attacks may observe)
# --------------------------------------------------------------------------

def null_feedback(m: int) -> Dict[str, jax.Array]:
    """Feedback when the defense publishes nothing (baseline aggregators /
    no defense): everyone good, zero distances, unbounded thresholds.
    Fixed shapes/dtypes so the attack state stays scan/vmap-stable."""
    return {
        "good": jnp.ones((m,), bool),
        "dist_to_med": jnp.zeros((m,), f32),
        "threshold": jnp.asarray(OPEN_LOOP_THRESHOLD, f32),
        "dist_to_med_A": jnp.zeros((m,), f32),
        "threshold_A": jnp.asarray(OPEN_LOOP_THRESHOLD, f32),
        "scores": jnp.zeros((m,), f32),
        "med": jnp.zeros((), jnp.int32),
        "n_good": jnp.asarray(m, f32),
    }


def feedback_from_info(info: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Project ``safeguard_step``'s info dict onto the public feedback the
    threat model of Remark 2.2 grants the adversary (both guards'
    thresholds and median distances, the good mask, the filter scores)."""
    return {
        "good": info["good"],
        "dist_to_med": jnp.asarray(info["dist_to_med_B"], f32),
        "threshold": jnp.asarray(info["threshold_B"], f32),
        "dist_to_med_A": jnp.asarray(info["dist_to_med_A"], f32),
        "threshold_A": jnp.asarray(info["threshold_A"], f32),
        "scores": jnp.asarray(info["scores_B"], f32),
        "med": jnp.asarray(info["med_B"], jnp.int32),
        "n_good": jnp.asarray(info["n_good"], f32),
    }


def defense_feedback(info: Dict[str, jax.Array], m: int
                     ) -> Dict[str, jax.Array]:
    """Project ANY defense's info dict (the unified protocol,
    ``core.defenses``) onto the public feedback surface.  Defenses that
    publish the full safeguard keys get the full projection; filtering
    defenses that only publish a membership mask (norm filter, DnC)
    expose their evictions through ``good``/``n_good`` over the
    open-loop defaults; pure-aggregation defenses reduce exactly to
    :func:`null_feedback`."""
    if "threshold_B" in info:
        return feedback_from_info(info)
    fb = null_feedback(m)
    if "good" in info:
        fb["good"] = info["good"]
    if "n_good" in info:
        fb["n_good"] = jnp.asarray(info["n_good"], f32)
    return fb


def _byz_dist_frac(fb, byz_mask):
    """Worst colluder's distance as a fraction of the live threshold,
    across BOTH guards (the binding one governs) — evicted colluders no
    longer count."""
    live = byz_mask & fb["good"]
    frac_b = (jnp.max(jnp.where(live, fb["dist_to_med"], 0.0))
              / jnp.maximum(fb["threshold"], 1e-12))
    frac_a = (jnp.max(jnp.where(live, fb["dist_to_med_A"], 0.0))
              / jnp.maximum(fb["threshold_A"], 1e-12))
    return jnp.maximum(frac_b, frac_a)


def _caught_count(fb, byz_mask):
    return (byz_mask & ~fb["good"]).sum().astype(f32)


# --------------------------------------------------------------------------
# Open-loop attacks
# --------------------------------------------------------------------------

def attack_none(grads, byz_mask, state, step, rng):
    return grads, state


def attack_sign_flip(grads, byz_mask, state, step, rng):
    neg = jax.tree.map(jnp.negative, grads)
    return _mix(grads, neg, byz_mask), state


def make_scaled_flip(scale: float):
    """Safeguard attack: ``-scale * g`` — tuned to stay under the filter
    thresholds (scale 0.6) or to occasionally trigger them (0.7)."""
    def attack(grads, byz_mask, state, step, rng):
        neg = jax.tree.map(lambda g: -scale * g, grads)
        return _mix(grads, neg, byz_mask), state
    return attack


def make_variance_attack(z_max: float = VARIANCE_Z, direction: float = -1.0):
    """[Baruch et al.] all Byzantine workers collude on ``mu + dir*z*sigma``."""
    def attack(grads, byz_mask, state, step, rng):
        mu, sd = _honest_stats(grads, byz_mask)
        adv = jax.tree.map(
            lambda m_, s_: (m_ + direction * z_max * s_)[None], mu, sd)
        adv = jax.tree.map(
            lambda a, g: jnp.broadcast_to(a, g.shape), adv, grads)
        return _mix(grads, adv, byz_mask), state
    return attack


def make_ipm(eps: float = 1.0):
    """Inner-product manipulation: report ``-eps * honest mean``."""
    def attack(grads, byz_mask, state, step, rng):
        mu, _ = _honest_stats(grads, byz_mask)
        adv = jax.tree.map(
            lambda m_, g: jnp.broadcast_to((-eps * m_)[None], g.shape),
            mu, grads)
        return _mix(grads, adv, byz_mask), state
    return attack


def make_delayed(delay: int):
    """Send the honest-mean gradient from ``delay`` steps ago.  State is a
    circular buffer of honest means (kept small: the benchmark models)."""
    def init(grads_like):
        return {
            "buffer": jax.tree.map(
                lambda l: jnp.zeros((delay,) + l.shape, f32),
                grads_like),
        }

    def attack(grads, byz_mask, state, step, rng):
        mu, _ = _honest_stats(grads, byz_mask)
        slot = step % delay
        old = jax.tree.map(lambda b: b[slot], state["buffer"])
        # before the buffer fills, replay the earliest honest mean we have
        ready = step >= delay
        adv_single = jax.tree.map(
            lambda o, m_: jnp.where(ready, o, m_.astype(f32)), old, mu)
        adv = jax.tree.map(
            lambda a, g: jnp.broadcast_to(a[None], g.shape), adv_single, grads)
        new_buf = jax.tree.map(
            lambda b, m_: b.at[slot].set(m_.astype(f32)),
            state["buffer"], mu)
        return _mix(grads, adv, byz_mask), {"buffer": new_buf}

    attack.init = init
    return attack


def make_burst(start: int, length: int, burst_scale: float = 5.0):
    """Appendix C.3: honest until ``start``, then ``-burst_scale * g`` for
    ``length`` steps, then honest again.  Circumvents *unwindowed* (whole
    -history) concentration filters; caught by the paper's sliding windows."""
    def attack(grads, byz_mask, state, step, rng):
        active = (step >= start) & (step < start + length)
        adv = jax.tree.map(lambda g: -burst_scale * g, grads)
        mixed = _mix(grads, adv, byz_mask)
        out = jax.tree.map(
            lambda h, x: jnp.where(active, x, h), grads, mixed)
        return out, state
    return attack


def make_random_noise(sigma: float = 1.0):
    def attack(grads, byz_mask, state, step, rng):
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        keys = jax.random.split(rng, len(leaves))
        noise = [sigma * jax.random.normal(k, l.shape, f32)
                 for k, l in zip(keys, leaves)]
        adv = jax.tree_util.tree_unflatten(treedef, noise)
        return _mix(grads, adv, byz_mask), state
    return attack


# --------------------------------------------------------------------------
# Attack protocol object
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Attack:
    """observe/act adversary.  ``act`` rewrites the Byzantine rows;
    ``observe`` (adaptive attacks only) folds the previous step's public
    defense feedback into the state the next ``act`` will read.  ``fn``
    is a legacy alias for ``act``."""
    name: str
    act: Callable
    init: Optional[Callable] = None   # state initializer (grads_like) -> state
    observe: Optional[Callable] = None  # (state, feedback, byz_mask) -> state
    data_attack: bool = False         # label flipping lives in the pipeline

    @property
    def fn(self) -> Callable:
        return self.act

    @property
    def adaptive(self) -> bool:
        return self.observe is not None


# Every adaptive controller keeps exactly one scalar "level" — the knob
# its observe() loop actually steers (aggression, z, scale, eps, boost).
# One of these keys per state dict, checked in this order.
_LEVEL_KEYS = ("aggr", "z", "scale", "eps", "boost")


def controller_level(state) -> Optional[jax.Array]:
    """The adaptive controller's scalar level from its state dict, or
    ``None`` for stateless / non-dict states.  This is what the obs
    layer traces as the ``attack_level`` metric: its direction reversals
    are the attack's observable phase boundaries (ramp <-> retreat)."""
    if not isinstance(state, dict):
        return None
    for key in _LEVEL_KEYS:
        if key in state:
            return jnp.asarray(state[key], f32)
    return None


# --------------------------------------------------------------------------
# Feedback-coupled adaptive attacks.  All state leaves are fixed-shape
# f32 scalars, so the state pytree scans and vmaps unchanged.  Every
# knob may be a traced scalar (campaign vmap axes) — only arithmetic.
# --------------------------------------------------------------------------

def make_adaptive_flip(init_scale=ADAPTIVE_DEFAULTS["adapt_init"],
                       up=ADAPTIVE_DEFAULTS["adapt_rate"],
                       down=ADAPTIVE_DEFAULTS["adapt_down"],
                       target=ADAPTIVE_DEFAULTS["adapt_target"],
                       aggr_min: float = 0.02, aggr_max: float = 4.0
                       ) -> Attack:
    """Threshold-tracking scaled flip: a multiplicative controller aiming
    the colluders' accumulated distance at ``target`` of the live eviction
    threshold.  The controlled quantity is the *aggression* ``u = 1 +
    scale``: a colluder sending ``-scale * g`` deviates from the honest
    accumulators in proportion to ``1 + scale``, so controlling ``u``
    multiplicatively can retreat smoothly through ``scale = 0`` (sending
    zeros) all the way to honest mimicry (``u -> 0``) when the live
    threshold leaves no room — exactly the bounded-harm regime the paper's
    concentration argument forces on any non-evicted worker.  Ratio
    ``target / frac`` (clipped to [down, up]) ramps while there is
    headroom and eases off approaching the threshold; a fresh eviction
    cuts ``u`` by ``down``."""
    def init(grads_like):
        return {"aggr": jnp.asarray(1.0 + init_scale, f32),
                "n_caught": jnp.zeros((), f32)}

    def act(grads, byz_mask, state, step, rng):
        s = state["aggr"] - 1.0
        adv = jax.tree.map(lambda g: -s * g.astype(f32), grads)
        return _mix(grads, adv, byz_mask), state

    def observe(state, fb, byz_mask):
        n_caught = _caught_count(fb, byz_mask)
        newly = n_caught > state["n_caught"]
        frac = _byz_dist_frac(fb, byz_mask)
        ratio = jnp.clip(target / jnp.maximum(frac, 1e-6), down, up)
        aggr = jnp.where(newly, state["aggr"] * down,
                         state["aggr"] * ratio)
        aggr = jnp.clip(aggr, aggr_min, aggr_max)
        return {"aggr": aggr, "n_caught": n_caught}

    return Attack("adaptive_flip", act, init=init, observe=observe)


def make_adaptive_variance(z_init=ADAPTIVE_DEFAULTS["adapt_init"],
                           up=ADAPTIVE_DEFAULTS["adapt_rate"],
                           down=ADAPTIVE_DEFAULTS["adapt_down"],
                           z_min: float = 0.01, z_max: float = VARIANCE_Z
                           ) -> Attack:
    """Eviction-aware [Baruch et al.]: collude on ``mu - z * sigma`` with
    ``z`` shrinking by ``down`` whenever a colluder is newly caught and
    creeping up by ``up`` toward ``z_max`` otherwise."""
    def init(grads_like):
        return {"z": jnp.asarray(z_init, f32),
                "n_caught": jnp.zeros((), f32)}

    def act(grads, byz_mask, state, step, rng):
        mu, sd = _honest_stats(grads, byz_mask)
        z = state["z"]
        adv = jax.tree.map(lambda m_, s_: (m_ - z * s_)[None], mu, sd)
        adv = jax.tree.map(
            lambda a, g: jnp.broadcast_to(a, g.shape), adv, grads)
        return _mix(grads, adv, byz_mask), state

    def observe(state, fb, byz_mask):
        n_caught = _caught_count(fb, byz_mask)
        newly = n_caught > state["n_caught"]
        z = jnp.where(newly, state["z"] * down, state["z"] * up)
        z = jnp.clip(z, z_min, z_max)
        return {"z": z, "n_caught": n_caught}

    return Attack("adaptive_variance", act, init=init, observe=observe)


def make_oscillating(init_scale=ADAPTIVE_DEFAULTS["adapt_init"],
                     up=ADAPTIVE_DEFAULTS["adapt_rate"],
                     high=ADAPTIVE_DEFAULTS["adapt_target"],
                     low=0.5 * ADAPTIVE_DEFAULTS["adapt_target"],
                     down=ADAPTIVE_DEFAULTS["adapt_down"],
                     scale_min: float = 0.02, scale_max: float = 4.0
                     ) -> Attack:
    """Hysteresis attacker: flip by ``-scale`` while the tracked distance
    sits below ``low`` of the threshold (ramping the scale by ``up`` while
    that headroom lasts), freeze (behave honestly, so the accumulated
    deviation stops growing and the next window reset drains it) once it
    crosses ``high``, and resume below ``low``.  A fresh eviction cuts
    the scale by ``down``."""
    def init(grads_like):
        return {"attacking": jnp.ones((), f32),
                "scale": jnp.asarray(init_scale, f32),
                "n_caught": jnp.zeros((), f32)}

    def act(grads, byz_mask, state, step, rng):
        s = state["scale"]
        active = state["attacking"] > 0.5
        adv = jax.tree.map(lambda g: -s * g.astype(f32), grads)
        mixed = _mix(grads, adv, byz_mask)
        out = jax.tree.map(lambda h, x: jnp.where(active, x, h),
                           grads, mixed)
        return out, state

    def observe(state, fb, byz_mask):
        n_caught = _caught_count(fb, byz_mask)
        newly = n_caught > state["n_caught"]
        frac = _byz_dist_frac(fb, byz_mask)
        attacking = jnp.where(frac >= high, 0.0,
                              jnp.where(frac <= low, 1.0,
                                        state["attacking"]))
        ramp = (attacking > 0.5) & (frac <= low)
        s = jnp.where(ramp, state["scale"] * up, state["scale"])
        s = jnp.where(newly, state["scale"] * down, s)
        return {"attacking": attacking,
                "scale": jnp.clip(s, scale_min, scale_max),
                "n_caught": n_caught}

    return Attack("oscillating", act, init=init, observe=observe)


def make_median_capture(eps_init=ADAPTIVE_DEFAULTS["adapt_init"],
                        up=ADAPTIVE_DEFAULTS["adapt_rate"],
                        down=ADAPTIVE_DEFAULTS["adapt_down"],
                        eps_min: float = 0.01, eps_max: float = 2.0
                        ) -> Attack:
    """Greedy concentration-median capture: all colluders report the
    identical vector ``(1 - eps) * mean(honest)``.  Zero intra-cluster
    distance plus hugging the honest cluster makes a colluder the
    empirical median; while the median is *held*, ``eps`` ramps greedily
    (dragging the reference point, pushing honest workers toward the
    threshold); losing the median — or a fresh eviction — retreats ``eps``
    back toward honest mimicry to recapture it."""
    def init(grads_like):
        return {"eps": jnp.asarray(eps_init, f32),
                "n_caught": jnp.zeros((), f32)}

    def act(grads, byz_mask, state, step, rng):
        mu, _ = _honest_stats(grads, byz_mask)
        e = state["eps"]
        adv = jax.tree.map(
            lambda m_, g: jnp.broadcast_to(((1.0 - e) * m_)[None], g.shape),
            mu, grads)
        return _mix(grads, adv, byz_mask), state

    def observe(state, fb, byz_mask):
        n_caught = _caught_count(fb, byz_mask)
        newly = n_caught > state["n_caught"]
        captured = jnp.take(byz_mask, fb["med"])
        eps = jnp.where(captured, state["eps"] * up, state["eps"] * down)
        eps = jnp.where(newly, state["eps"] * down, eps)
        eps = jnp.clip(eps, eps_min, eps_max)
        return {"eps": eps, "n_caught": n_caught}

    return Attack("median_capture", act, init=init, observe=observe)


def make_saddle_push(dirs: jax.Array,
                     boost_init=ADAPTIVE_DEFAULTS["adapt_init"],
                     up=ADAPTIVE_DEFAULTS["adapt_rate"],
                     down=ADAPTIVE_DEFAULTS["adapt_down"],
                     target=ADAPTIVE_DEFAULTS["adapt_target"],
                     boost_min: float = 0.02, boost_max: float = 8.0
                     ) -> Attack:
    """Saddle-point attack [Yin et al., arXiv:1806.05358] on the
    planted-saddle family (``repro.data.saddle``; DESIGN.md §14).

    ``dirs`` is the static ``(k, d)`` orthonormal basis of the planted
    negative-curvature subspace — Remark 2.2's threat model lets the
    colluders know the objective, so they know exactly which components
    drive escape.  ``act`` reports, for every Byzantine row,

        mu - P_esc mu  -  (n_h / n_b) * boost * P_esc mu

    i.e. honest mimicry off the escape subspace (zero deviation there —
    the concentration filter sees nothing) and a scaled *cancellation*
    on it: the aggregate mean's escape component becomes ``(n_h / m) *
    (1 - boost) * P_esc mu``, so ``boost = 1`` suppresses the honest
    escape drive exactly and ``boost > 1`` reverses it (gradient
    pointing *away* from the saddle gets flipped into a pull back onto
    it).  The colluders' deviation from the honest mean lives entirely
    in the k-dim escape subspace with norm ``(n_h/n_b) * boost *
    ||P_esc mu||`` — tiny near the saddle where ``||P_esc mu|| ~
    noise``, growing as the iterate escapes, which is exactly the
    signal the safeguard's windowed accumulators concentrate on.

    ``observe`` is the honest-mimicry budget: the same multiplicative
    controller as ``adaptive_flip`` ramps ``boost`` toward the live
    threshold's ``target`` fraction and backs off on a fresh eviction,
    so under a filtering defense the total pull-back the colluders can
    exert is bounded by the threshold — the paper's concentration
    argument then forces escape (the theorem-level separation the
    saddle campaign asserts).  Under no defense the null feedback's
    unbounded headroom lets ``boost`` ramp to ``boost_max`` and the
    iterate provably stalls.
    """
    def init(grads_like):
        return {"boost": jnp.asarray(boost_init, f32),
                "n_caught": jnp.zeros((), f32)}

    def act(grads, byz_mask, state, step, rng):
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        if len(leaves) != 1:
            raise ValueError("saddle_push assumes the planted-saddle "
                             "task layout: a single (m, d) gradient leaf")
        g = leaves[0].astype(f32)                        # (m, d)
        w = (~byz_mask).astype(f32)
        n_h = jnp.maximum(w.sum(), 1.0)
        n_b = jnp.maximum(byz_mask.sum().astype(f32), 1.0)
        mu = (g * w[:, None]).sum(axis=0) / n_h          # honest mean (d,)
        u = dirs @ mu                                    # (k,) escape drive
        esc = dirs.T @ u                                 # P_esc mu  (d,)
        adv = (mu - esc) - (n_h / n_b) * state["boost"] * esc
        adv = jnp.broadcast_to(adv[None], g.shape)
        mixed = _mix(grads, jax.tree_util.tree_unflatten(treedef, [adv]),
                     byz_mask)
        return mixed, state

    def observe(state, fb, byz_mask):
        n_caught = _caught_count(fb, byz_mask)
        newly = n_caught > state["n_caught"]
        frac = _byz_dist_frac(fb, byz_mask)
        ratio = jnp.clip(target / jnp.maximum(frac, 1e-6), down, up)
        boost = jnp.where(newly, state["boost"] * down,
                          state["boost"] * ratio)
        boost = jnp.clip(boost, boost_min, boost_max)
        return {"boost": boost, "n_caught": n_caught}

    return Attack("saddle_push", act, init=init, observe=observe)


# --------------------------------------------------------------------------

def make_registry(delay: int = 64, burst_start: Optional[int] = None,
                  burst_length: int = 50, *,
                  steps: Optional[int] = None) -> Dict[str, Attack]:
    """Attack registry.

    ``burst_start=None`` derives the burst window from the trial length
    (``steps // 3``) so the burst always fires; an *explicit* start that
    cannot fire within a known trial length fails loudly instead of
    silently benchmarking honest execution.  ``steps=None`` (open-ended
    runs: examples, serving) keeps the legacy start of 200.

    The adaptive entries use their factory defaults, which are the same
    :data:`ADAPTIVE_DEFAULTS` the campaign layer's ``Scenario.adapt_*``
    fields read — the legacy Trainer path and the campaign engine run
    the same attack under the same name by construction.
    """
    if burst_start is None:
        burst_start = steps // 3 if steps is not None else 200
    elif steps is not None and burst_start >= steps:
        raise ValueError(
            f"burst attack can never fire: burst_start={burst_start} >= "
            f"steps={steps} (use burst_start=None to derive it)")
    delayed = make_delayed(delay)
    return {
        "none": Attack("none", attack_none),
        "sign_flip": Attack("sign_flip", attack_sign_flip),
        "safeguard_x0.6": Attack("safeguard_x0.6", make_scaled_flip(0.6)),
        "safeguard_x0.7": Attack("safeguard_x0.7", make_scaled_flip(0.7)),
        "variance": Attack("variance", make_variance_attack(VARIANCE_Z)),
        "ipm": Attack("ipm", make_ipm(1.0)),
        "delayed": Attack("delayed", delayed, init=delayed.init),
        "burst": Attack("burst",
                        make_burst(burst_start, burst_length, 5.0)),
        "random_noise": Attack("random_noise", make_random_noise(1.0)),
        "label_flip": Attack("label_flip", attack_none, data_attack=True),
        "adaptive_flip": make_adaptive_flip(),
        "adaptive_variance": make_adaptive_variance(),
        "oscillating": make_oscillating(),
        "median_capture": make_median_capture(),
    }
