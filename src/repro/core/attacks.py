"""Byzantine attack library (paper Section 5 + Appendix C).

An attack is a pure function transforming the stacked honest per-worker
gradients into what the master actually receives:

    attack(grads, byz_mask, state, step, rng) -> (grads', state')

``byz_mask`` is a static (m,) bool array marking Byzantine workers; honest
rows are passed through untouched.  Attacks may collude: they see the full
honest stack (the strongest, paper-consistent threat model — Remark 2.2
allows byzantine vectors to depend on everything up to the current step).

Attacks implemented:
  * ``none``              — honest execution;
  * ``sign_flip``         — send the negated gradient;
  * ``scaled_flip``       — send ``-scale * g`` (the paper's *safeguard
    attack*, scale 0.6 / 0.7, an inner-product-manipulation instance);
  * ``delayed``           — send the gradient from ``D`` steps ago
    (implemented with a circular buffer of the honest mean gradient);
  * ``variance``          — [Baruch et al. 2019] collusive attack: every
    Byzantine worker reports ``mu - z * sigma`` per coordinate, the largest
    mean shift statistically indistinguishable within one step;
  * ``ipm``               — inner-product manipulation [Xie et al. 2020]:
    report ``-eps * mean(honest)``;
  * ``burst``             — Appendix C.3 attack on the convex algorithm of
    Alistarh et al. 2018: behave honestly except for a contiguous window of
    steps in which the gradient is scaled by ``-burst_scale``;
  * ``random_noise``      — i.i.d. Gaussian junk (sanity baseline).

Label-flipping is a *data* attack, implemented in ``repro.data`` (the
Byzantine worker computes a true gradient of a corrupted loss).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import tree_utils as tu


def _mix(honest, adversarial, byz_mask):
    """Per-worker select: byzantine rows from ``adversarial``."""
    def one(h, a):
        mshape = (-1,) + (1,) * (h.ndim - 1)
        return jnp.where(byz_mask.reshape(mshape), a.astype(h.dtype), h)
    return jax.tree.map(one, honest, adversarial)


def _honest_stats(grads, byz_mask):
    """Mean and std over honest workers only, per coordinate."""
    w = (~byz_mask).astype(jnp.float32)
    n = jnp.maximum(w.sum(), 1.0)

    def stats(g):
        gw = g.astype(jnp.float32)
        wshape = (-1,) + (1,) * (g.ndim - 1)
        mu = (gw * w.reshape(wshape)).sum(axis=0) / n
        var = (((gw - mu[None]) ** 2) * w.reshape(wshape)).sum(axis=0) / n
        return mu, jnp.sqrt(var + 1e-12)
    mus, sigmas = {}, {}
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    out = [stats(l) for l in leaves]
    mu_tree = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    sd_tree = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return mu_tree, sd_tree


# --------------------------------------------------------------------------

def attack_none(grads, byz_mask, state, step, rng):
    return grads, state


def attack_sign_flip(grads, byz_mask, state, step, rng):
    neg = jax.tree.map(jnp.negative, grads)
    return _mix(grads, neg, byz_mask), state


def make_scaled_flip(scale: float):
    """Safeguard attack: ``-scale * g`` — tuned to stay under the filter
    thresholds (scale 0.6) or to occasionally trigger them (0.7)."""
    def attack(grads, byz_mask, state, step, rng):
        neg = jax.tree.map(lambda g: -scale * g, grads)
        return _mix(grads, neg, byz_mask), state
    return attack


def make_variance_attack(z_max: float = 0.3, direction: float = -1.0):
    """[Baruch et al.] all Byzantine workers collude on ``mu + dir*z*sigma``."""
    def attack(grads, byz_mask, state, step, rng):
        mu, sd = _honest_stats(grads, byz_mask)
        adv = jax.tree.map(
            lambda m_, s_: (m_ + direction * z_max * s_)[None], mu, sd)
        adv = jax.tree.map(
            lambda a, g: jnp.broadcast_to(a, g.shape), adv, grads)
        return _mix(grads, adv, byz_mask), state
    return attack


def make_ipm(eps: float = 1.0):
    """Inner-product manipulation: report ``-eps * honest mean``."""
    def attack(grads, byz_mask, state, step, rng):
        mu, _ = _honest_stats(grads, byz_mask)
        adv = jax.tree.map(
            lambda m_, g: jnp.broadcast_to((-eps * m_)[None], g.shape),
            mu, grads)
        return _mix(grads, adv, byz_mask), state
    return attack


def make_delayed(delay: int):
    """Send the honest-mean gradient from ``delay`` steps ago.  State is a
    circular buffer of honest means (kept small: the benchmark models)."""
    def init(grads_like):
        return {
            "buffer": jax.tree.map(
                lambda l: jnp.zeros((delay,) + l.shape, jnp.float32),
                grads_like),
        }

    def attack(grads, byz_mask, state, step, rng):
        mu, _ = _honest_stats(grads, byz_mask)
        slot = step % delay
        old = jax.tree.map(lambda b: b[slot], state["buffer"])
        # before the buffer fills, replay the earliest honest mean we have
        ready = step >= delay
        adv_single = jax.tree.map(
            lambda o, m_: jnp.where(ready, o, m_.astype(jnp.float32)), old, mu)
        adv = jax.tree.map(
            lambda a, g: jnp.broadcast_to(a[None], g.shape), adv_single, grads)
        new_buf = jax.tree.map(
            lambda b, m_: b.at[slot].set(m_.astype(jnp.float32)),
            state["buffer"], mu)
        return _mix(grads, adv, byz_mask), {"buffer": new_buf}

    attack.init = init
    return attack


def make_burst(start: int, length: int, burst_scale: float = 5.0):
    """Appendix C.3: honest until ``start``, then ``-burst_scale * g`` for
    ``length`` steps, then honest again.  Circumvents *unwindowed* (whole
    -history) concentration filters; caught by the paper's sliding windows."""
    def attack(grads, byz_mask, state, step, rng):
        active = (step >= start) & (step < start + length)
        adv = jax.tree.map(lambda g: -burst_scale * g, grads)
        mixed = _mix(grads, adv, byz_mask)
        out = jax.tree.map(
            lambda h, x: jnp.where(active, x, h), grads, mixed)
        return out, state
    return attack


def make_random_noise(sigma: float = 1.0):
    def attack(grads, byz_mask, state, step, rng):
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        keys = jax.random.split(rng, len(leaves))
        noise = [sigma * jax.random.normal(k, l.shape, jnp.float32)
                 for k, l in zip(keys, leaves)]
        adv = jax.tree_util.tree_unflatten(treedef, noise)
        return _mix(grads, adv, byz_mask), state
    return attack


# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Attack:
    name: str
    fn: Callable
    init: Optional[Callable] = None   # state initializer (grads_like) -> state
    data_attack: bool = False         # label flipping lives in the pipeline


def make_registry(delay: int = 64, burst_start: int = 200,
                  burst_length: int = 50) -> Dict[str, Attack]:
    delayed = make_delayed(delay)
    return {
        "none": Attack("none", attack_none),
        "sign_flip": Attack("sign_flip", attack_sign_flip),
        "safeguard_x0.6": Attack("safeguard_x0.6", make_scaled_flip(0.6)),
        "safeguard_x0.7": Attack("safeguard_x0.7", make_scaled_flip(0.7)),
        "variance": Attack("variance", make_variance_attack(0.3)),
        "ipm": Attack("ipm", make_ipm(1.0)),
        "delayed": Attack("delayed", delayed, init=delayed.init),
        "burst": Attack("burst",
                        make_burst(burst_start, burst_length, 5.0)),
        "random_noise": Attack("random_noise", make_random_noise(1.0)),
        "label_flip": Attack("label_flip", attack_none, data_attack=True),
    }
