"""SafeguardSGD (Allen-Zhu, Ebrahimian, Li, Alistarh — ICLR 2021).

Implements the paper's Algorithm 1 (double safe guard) and Algorithm 2
(single safe guard) as a pure-JAX aggregation layer:

  * per-worker accumulators ``A_i`` (long window ``T1``) and ``B_i`` (short
    window ``T0``) of the reported gradients, each divided by the number of
    currently-good workers, reset at every multiple of the window length;
  * a *concentration median* ``A_med``: either the paper's theoretical rule
    (any good worker whose accumulator is within threshold of a strict
    majority) or the empirical rule of Appendix C.1 (argmin over workers of
    the ``ceil(m/2 + 1)``-th smallest pairwise distance, with an automatic
    threshold ``scale * max(score, floor)``);
  * permanent eviction of any worker farther than the threshold from the
    median — within the current window; an optional periodic *full reset*
    (Section 5) restores evicted workers every ``reset_period`` steps,
    which tolerates transient failures and bounded ID relabeling;
  * the SGD direction: mean of the reported gradients over currently-good
    workers, optionally plus the isotropic Gaussian perturbation
    ``xi ~ N(0, nu^2 I)`` used by the theory to escape saddle points.

Two state representations are provided:

  * **exact** (paper-faithful): the accumulators are full stacked gradient
    pytrees, ``O(m * d)`` state; pairwise distances via the Gram matrix
    (``core.tree_utils.tree_gram``) which shards cleanly;
  * **sketched** (beyond paper, DESIGN.md §3): accumulate CountSketch
    projections, ``O(m * r * k)`` state, identical filter decisions up to
    JL distortion.

Everything is ``jit``-safe: masks instead of dynamic shapes, ``where``
instead of branches.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import tree_utils as tu
from repro.core import sketch as sk


# --------------------------------------------------------------------------
# Config
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SafeguardConfig:
    """Hyper-parameters of the safeguard filter.

    ``mode``:
      * ``"double"`` — Algorithm 1 (windows ``T0 <= T1``, thresholds
        ``thresh0 <= thresh1``);
      * ``"single"`` — Algorithm 2 (only the ``B``/short guard is active).
    ``rule``:
      * ``"empirical"`` — Appendix C.1 scoring + auto threshold;
      * ``"theoretical"`` — fixed thresholds ``thresh0/1 = Theta(sqrt(T))``,
        majority-ball median, eviction at ``2 * thresh``.
    """
    m: int                      # number of workers
    T0: int = 100               # short window length (steps)
    T1: int = 600               # long window length (steps)
    mode: str = "double"        # "double" | "single"
    rule: str = "empirical"     # "empirical" | "theoretical"
    # theoretical rule: fixed thresholds (paper: 8 * sqrt(T log(16mT/p)))
    thresh0: float = 0.0
    thresh1: float = 0.0
    # empirical rule (Appendix C.1)
    threshold_scale: float = 1.5
    threshold_floor: float = 5.0
    # Gaussian perturbation xi ~ N(0, nu^2 I); nu = 0 disables (paper C.1)
    nu: float = 0.0
    # Section 5: restore all workers every ``reset_period`` steps (0 = never)
    reset_period: int = 0
    # aggregate over the pre-filter good set (paper Alg 1 line 12 uses
    # good_t, i.e. eviction takes effect next step)
    aggregate_prefilter: bool = True
    # sketched safeguard (beyond paper)
    use_sketch: bool = False
    sketch_k: int = 2048
    sketch_reps: int = 4
    sketch_seed: int = 0
    # dtype for exact accumulators
    acc_dtype: Any = jnp.float32

    def __post_init__(self):
        if self.mode not in ("double", "single"):
            raise ValueError(f"bad mode {self.mode!r}")
        if self.rule not in ("empirical", "theoretical"):
            raise ValueError(f"bad rule {self.rule!r}")
        if self.T0 > self.T1:
            raise ValueError("need T0 <= T1")
        if self.rule == "theoretical" and self.thresh0 <= 0:
            raise ValueError("theoretical rule needs explicit thresholds")

    @staticmethod
    def theoretical_thresholds(T0: int, T1: int, m: int, p: float = 0.01,
                               V: float = 1.0):
        """Paper Lemma 3.2 / B.2 thresholds ``8 sqrt(T log(16 m T / p))``.

        ``V`` rescales for gradient-noise bound != 1 (the paper normalizes
        V = 1; thresholds are proportional to V).
        """
        import math
        t0 = 8.0 * V * math.sqrt(T0 * math.log(16 * m * T1 / p)) / m
        t1 = 8.0 * V * math.sqrt(T1 * math.log(16 * m * T1 / p)) / m
        return t0, t1


# --------------------------------------------------------------------------
# State
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SafeguardState:
    """Carried across steps. ``A``/``B`` are stacked pytrees in exact mode,
    ``(m, r*k)`` sketch matrices in sketched mode."""
    good: jax.Array             # (m,) bool — currently-good mask
    step: jax.Array             # () int32
    A: Any                      # long-window accumulator (None in single mode)
    B: Any                      # short-window accumulator
    evicted_at: jax.Array       # (m,) int32, -1 if never evicted (diagnostic)


def init_state(cfg: SafeguardConfig, grads_like) -> SafeguardState:
    """``grads_like``: a parameter pytree (NOT stacked) used for shapes."""
    if cfg.use_sketch:
        acc = jnp.zeros((cfg.m, cfg.sketch_reps * cfg.sketch_k), jnp.float32)
        A = acc if cfg.mode == "double" else None
        B = acc
    else:
        def stacked(leaf):
            return jnp.zeros((cfg.m,) + leaf.shape, cfg.acc_dtype)
        acc = jax.tree.map(stacked, grads_like)
        A = acc if cfg.mode == "double" else None
        B = jax.tree.map(stacked, grads_like)
    return SafeguardState(
        good=jnp.ones((cfg.m,), bool),
        step=jnp.zeros((), jnp.int32),
        A=A,
        B=B,
        evicted_at=-jnp.ones((cfg.m,), jnp.int32),
    )


# --------------------------------------------------------------------------
# Filter internals
# --------------------------------------------------------------------------

def _empirical_filter(sqdist: jax.Array, good: jax.Array, m: int,
                      scale: float, floor: float):
    """Appendix C.1: score_i = ceil(m/2+1)-th smallest distance over good j;
    med = argmin score;  evict j with d(j, med) >= scale * max(S, floor).

    Returns (pass mask, med index, threshold, scores).
    """
    big = jnp.float32(1e30)
    dist = jnp.sqrt(sqdist)
    # mask non-good rows/cols
    dist = jnp.where(good[None, :], dist, big)
    dist = jnp.where(good[:, None], dist, big)
    k = int(-(-m // 2)) + 1        # ceil(m/2) + 1 entries -> index k-1
    k = min(k, m)
    sorted_d = jnp.sort(dist, axis=1)
    scores = sorted_d[:, k - 1]
    scores = jnp.where(good, scores, big)
    med = jnp.argmin(scores)
    S = scores[med]
    thresh = scale * jnp.maximum(S, floor)
    ok = dist[:, med] < thresh
    ok = ok | (jnp.arange(m) == med)
    return ok & good, med, thresh, scores


def _theoretical_filter(sqdist: jax.Array, good: jax.Array, m: int,
                        thresh: float):
    """Paper Algorithm 1 lines 9-11: med = any good i with a strict majority
    of workers within ``thresh``;  evict at ``2 * thresh``."""
    big = jnp.float32(1e30)
    dist = jnp.sqrt(sqdist)
    dist = jnp.where(good[None, :], dist, big)
    dist = jnp.where(good[:, None], dist, big)
    within = (dist <= thresh) & good[None, :] & good[:, None]
    counts = within.sum(axis=1)
    valid = good & (counts > m // 2)
    # fall back to max-count worker when the majority event fails
    counts_masked = jnp.where(good, counts, -1)
    med = jnp.where(valid.any(), jnp.argmax(valid), jnp.argmax(counts_masked))
    ok = dist[:, med] <= 2.0 * thresh
    ok = ok | (jnp.arange(m) == med)
    return ok & good, med, jnp.float32(2.0 * thresh), counts.astype(jnp.float32)


def _accumulate_exact(acc, grads, reset, inv_ngood, dtype):
    """acc <- [reset ? 0 : acc] + grads / n_good, in acc dtype."""
    def one(a, g):
        a = jnp.where(reset, jnp.zeros_like(a), a)
        return a + g.astype(dtype) * inv_ngood
    return jax.tree.map(one, acc, grads)


# --------------------------------------------------------------------------
# The step
# --------------------------------------------------------------------------

def safeguard_step(state: SafeguardState, grads, cfg: SafeguardConfig,
                   rng: Optional[jax.Array] = None):
    """One master-side safeguard step.

    Args:
      state:  SafeguardState.
      grads:  stacked per-worker gradient pytree, leaves ``(m, ...)``.  The
        Byzantine simulation (attacks) has already been applied.
      cfg:    SafeguardConfig.
      rng:    PRNG key for the Gaussian perturbation (required if nu > 0).

    Returns:
      (new_state, aggregated gradient pytree, info dict)
    """
    m = cfg.m
    t = state.step
    good = state.good

    # Section 5 relaxation: periodically restore every worker.
    if cfg.reset_period > 0:
        restore = (t % cfg.reset_period) == 0
        good = jnp.where(restore, jnp.ones_like(good), good)

    n_good = jnp.maximum(good.sum(), 1).astype(jnp.float32)
    inv_ngood = 1.0 / n_good

    reset_B = (t % cfg.T0) == 0
    reset_A = (t % cfg.T1) == 0

    if cfg.use_sketch:
        gsk = sk.sketch_tree(grads, k=cfg.sketch_k, reps=cfg.sketch_reps,
                             seed=cfg.sketch_seed)
        B = jnp.where(reset_B, 0.0, state.B) + gsk * inv_ngood
        A = None
        if cfg.mode == "double":
            A = jnp.where(reset_A, 0.0, state.A) + gsk * inv_ngood
        sqdist_B = sk.sketch_pairwise_sqdist(B)
        sqdist_A = sk.sketch_pairwise_sqdist(A) if A is not None else None
    else:
        B = _accumulate_exact(state.B, grads, reset_B, inv_ngood,
                              cfg.acc_dtype)
        A = None
        if cfg.mode == "double":
            A = _accumulate_exact(state.A, grads, reset_A, inv_ngood,
                                  cfg.acc_dtype)
        sqdist_B = tu.tree_pairwise_sqdist(B)
        sqdist_A = tu.tree_pairwise_sqdist(A) if A is not None else None

    if cfg.rule == "empirical":
        okB, medB, thB, scoresB = _empirical_filter(
            sqdist_B, good, m, cfg.threshold_scale, cfg.threshold_floor)
        if cfg.mode == "double":
            okA, medA, thA, _ = _empirical_filter(
                sqdist_A, good, m, cfg.threshold_scale, cfg.threshold_floor)
        else:
            okA, medA, thA = jnp.ones_like(okB), medB, thB
    else:
        okB, medB, thB, scoresB = _theoretical_filter(
            sqdist_B, good, m, cfg.thresh0)
        if cfg.mode == "double":
            okA, medA, thA, _ = _theoretical_filter(
                sqdist_A, good, m, cfg.thresh1)
        else:
            okA, medA, thA = jnp.ones_like(okB), medB, thB

    new_good = good & okA & okB

    newly_evicted = good & ~new_good
    evicted_at = jnp.where(newly_evicted, t, state.evicted_at)

    # SGD direction over good_t (pre-filter, paper line 12) or good_{t+1}.
    agg_mask = good if cfg.aggregate_prefilter else new_good
    agg = tu.tree_masked_mean(grads, agg_mask)

    if cfg.nu > 0.0:
        if rng is None:
            raise ValueError("nu > 0 requires an rng key")
        keys = jax.random.split(rng, len(jax.tree_util.tree_leaves(agg)))
        keys = iter(list(keys))

        def add_noise(leaf):
            k = next(keys)
            return leaf + cfg.nu * jax.random.normal(k, leaf.shape, leaf.dtype)
        agg = jax.tree.map(add_noise, agg)

    new_state = SafeguardState(
        good=new_good,
        step=t + 1,
        A=A if cfg.mode == "double" else state.A,
        B=B,
        evicted_at=evicted_at,
    )
    info = {
        "n_good": n_good,
        "med_B": medB,
        "med_A": medA,
        "threshold_B": thB,
        "threshold_A": thA,
        "dist_to_med_B": jnp.sqrt(sqdist_B)[:, medB],
        "scores_B": scoresB,
        "newly_evicted": newly_evicted,
        "good": new_good,
    }
    return new_state, agg, info
