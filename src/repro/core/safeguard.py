"""SafeguardSGD (Allen-Zhu, Ebrahimian, Li, Alistarh — ICLR 2021).

Implements the paper's Algorithm 1 (double safe guard) and Algorithm 2
(single safe guard) as a pure-JAX aggregation layer:

  * per-worker accumulators ``A_i`` (long window ``T1``) and ``B_i`` (short
    window ``T0``) of the reported gradients, each divided by the number of
    currently-good workers, reset at every multiple of the window length;
  * a *concentration median* ``A_med``: either the paper's theoretical rule
    (any good worker whose accumulator is within threshold of a strict
    majority) or the empirical rule of Appendix C.1 (argmin over workers of
    the ``ceil(m/2 + 1)``-th smallest pairwise distance, with an automatic
    threshold ``scale * max(score, floor)``);
  * permanent eviction of any worker farther than the threshold from the
    median — within the current window; an optional periodic *full reset*
    (Section 5) restores evicted workers every ``reset_period`` steps,
    which tolerates transient failures and bounded ID relabeling;
  * the SGD direction: mean of the reported gradients over currently-good
    workers, optionally plus the isotropic Gaussian perturbation
    ``xi ~ N(0, nu^2 I)`` used by the theory to escape saddle points.

Three state representations are provided (DESIGN.md §6):

  * **flat** (default): the accumulators are single ``(m, d_pad)``
    matrices in one fixed ``tree_flatten`` layout (:class:`FlatLayout`,
    computed once at :func:`init_state`; :func:`unflatten_row` recovers a
    parameter pytree for diagnostics).  The accumulate-and-reset update is
    one fused in-place chain of column-slice adds into the buffer (the
    reset ``where`` is the only copy; every scatter after it updates in
    place), and the pairwise-distance pass runs on the whole buffer at
    once — the ``safeguard_filter`` Pallas Gram kernel
    (``backend="pallas"``, interpret mode on CPU with the package's
    ``ref.py`` as numerics oracle), a single XLA ``dot_general``
    (``backend="xla"``, the choice under a sharded mesh, DESIGN.md §3), or
    the fully fused accumulate+distance kernel streaming each d-tile
    through VMEM exactly once (``backend="pallas_fused"``, the TPU hot
    path — it needs the gradients flattened to one matrix first, which is
    why it is not the CPU default);
  * **stacked** (paper-faithful reference): full stacked gradient pytrees,
    pairwise distances leaf-by-leaf via ``core.tree_utils.tree_gram``.
    Kept as the numerics oracle and for model-axis-sharded giants whose
    flat buffer would not fit a single row on one device;
  * **sketched** (beyond paper, DESIGN.md §3): accumulate CountSketch
    projections, ``O(m * r * k)`` state, identical filter decisions up to
    JL distortion.

Everything is ``jit``-safe: masks instead of dynamic shapes, ``where``
instead of branches; the flat layout is static pytree metadata.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import tree_utils as tu
from repro.core import sketch as sk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# --------------------------------------------------------------------------
# Flat buffer layout
# --------------------------------------------------------------------------

_LANE = 128           # TPU lane multiple (feature axis)
_BLOCK_D = 512        # preferred d-tile of the Pallas kernel


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Static description of the one-time ``tree_flatten`` of the model's
    gradient pytree into a single ``(m_pad, d_pad)`` row-per-worker buffer.

    Hashable (it rides along as pytree *metadata* of
    :class:`SafeguardState`), computed exactly once at :func:`init_state`.
    ``offsets[i]:offsets[i]+sizes[i]`` is leaf ``i``'s column slice.
    """
    treedef: Any                      # jax PyTreeDef of the param pytree
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[str, ...]
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]
    d: int                            # true model dimension
    d_padded: int                     # d rounded up to a kernel-tile multiple


def make_layout(grads_like) -> FlatLayout:
    """``grads_like``: a parameter pytree (NOT worker-stacked).  The feature
    axis is padded to the Pallas tile multiple (zeros never change
    distances), so every downstream op is MXU-aligned with no per-step
    re-padding."""
    leaves, treedef = jax.tree_util.tree_flatten(grads_like)
    if not leaves:
        raise ValueError("empty gradient pytree")
    shapes, dtypes, offsets, sizes = [], [], [], []
    off = 0
    for leaf in leaves:
        size = 1
        for s in leaf.shape:
            size *= int(s)
        shapes.append(tuple(int(s) for s in leaf.shape))
        dtypes.append(str(jnp.dtype(leaf.dtype)))
        offsets.append(off)
        sizes.append(size)
        off += size
    d = off
    pad_to = _BLOCK_D if d >= _BLOCK_D else _LANE
    d_padded = d + (-d) % pad_to
    return FlatLayout(treedef=treedef, shapes=tuple(shapes),
                      dtypes=tuple(dtypes), offsets=tuple(offsets),
                      sizes=tuple(sizes), d=d, d_padded=d_padded)


def flatten_stacked(grads, layout: FlatLayout) -> jax.Array:
    """Worker-stacked pytree (leaves ``(m, ...)``) -> ``(m, d_pad)`` f32
    matrix in the layout's column order, zero-padded feature columns."""
    leaves = jax.tree_util.tree_leaves(grads)
    m = leaves[0].shape[0]
    parts = [leaf.astype(jnp.float32).reshape(m, -1) for leaf in leaves]
    flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if flat.shape[1] != layout.d:
        raise ValueError(
            f"gradient pytree has d={flat.shape[1]}, layout has {layout.d}")
    if layout.d_padded != layout.d:
        flat = jnp.pad(flat, ((0, 0), (0, layout.d_padded - layout.d)))
    return flat


def unflatten_row(row: jax.Array, layout: FlatLayout):
    """Inverse of :func:`flatten_stacked` for one worker row ``(d_pad,)``:
    recovers the parameter-pytree view of an accumulator (diagnostics)."""
    out = []
    for shape, dt, off, size in zip(layout.shapes, layout.dtypes,
                                    layout.offsets, layout.sizes):
        out.append(row[off:off + size].reshape(shape).astype(dt))
    return jax.tree_util.tree_unflatten(layout.treedef, out)


# --------------------------------------------------------------------------
# Config
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SafeguardConfig:
    """Hyper-parameters of the safeguard filter.

    ``mode``:
      * ``"double"`` — Algorithm 1 (windows ``T0 <= T1``, thresholds
        ``thresh0 <= thresh1``);
      * ``"single"`` — Algorithm 2 (only the ``B``/short guard is active).
    ``rule``:
      * ``"empirical"`` — Appendix C.1 scoring + auto threshold;
      * ``"theoretical"`` — fixed thresholds ``thresh0/1 = Theta(sqrt(T))``,
        majority-ball median, eviction at ``2 * thresh``.
    ``engine``:
      * ``"flat"`` — flat-buffer streaming accumulators (default);
      * ``"stacked"`` — paper-faithful stacked-pytree reference.
    ``backend`` (flat engine only):
      * ``"pallas"`` — in-place scatter accumulate + the blocked Pallas
        Gram kernel (interpret mode off-TPU);
      * ``"pallas_fused"`` — single streamed accumulate+distance kernel
        (flattens the gradients to one matrix per step; the TPU hot path);
        requires f32 accumulators, else falls back to ``"xla"``;
      * ``"xla"`` — in-place scatter accumulate + one XLA ``dot_general``;
        use under a sharded mesh where a single-device kernel cannot be
        partitioned (DESIGN.md §3).
    """
    m: int                      # number of workers
    T0: int = 100               # short window length (steps)
    T1: int = 600               # long window length (steps)
    mode: str = "double"        # "double" | "single"
    rule: str = "empirical"     # "empirical" | "theoretical"
    # theoretical rule: fixed thresholds (paper: 8 * sqrt(T log(16mT/p)))
    thresh0: float = 0.0
    thresh1: float = 0.0
    # empirical rule (Appendix C.1)
    threshold_scale: float = 1.5
    threshold_floor: float = 5.0
    # Gaussian perturbation xi ~ N(0, nu^2 I); nu = 0 disables (paper C.1)
    nu: float = 0.0
    # Section 5: restore all workers every ``reset_period`` steps (0 = never)
    reset_period: int = 0
    # aggregate over the pre-filter good set (paper Alg 1 line 12 uses
    # good_t, i.e. eviction takes effect next step)
    aggregate_prefilter: bool = True
    # sketched safeguard (beyond paper)
    use_sketch: bool = False
    sketch_k: int = 2048
    sketch_reps: int = 4
    sketch_seed: int = 0
    # exact accumulators: state representation + distance implementation
    engine: str = "flat"        # "flat" | "stacked"
    backend: str = "pallas"     # "pallas" | "xla"
    acc_dtype: Any = jnp.float32

    def __post_init__(self):
        if self.mode not in ("double", "single"):
            raise ValueError(f"bad mode {self.mode!r}")
        if self.rule not in ("empirical", "theoretical"):
            raise ValueError(f"bad rule {self.rule!r}")
        if self.engine not in ("flat", "stacked"):
            raise ValueError(f"bad engine {self.engine!r}")
        if self.backend not in ("pallas", "pallas_fused", "xla"):
            raise ValueError(f"bad backend {self.backend!r}")
        if self.T0 > self.T1:
            raise ValueError("need T0 <= T1")
        if self.rule == "theoretical" and self.thresh0 <= 0:
            raise ValueError("theoretical rule needs explicit thresholds")

    @staticmethod
    def theoretical_thresholds(T0: int, T1: int, m: int, p: float = 0.01,
                               V: float = 1.0):
        """Paper Lemma 3.2 / B.2 thresholds ``8 sqrt(T log(16 m T / p))``.

        ``V`` rescales for gradient-noise bound != 1 (the paper normalizes
        V = 1; thresholds are proportional to V).
        """
        import math
        t0 = 8.0 * V * math.sqrt(T0 * math.log(16 * m * T1 / p)) / m
        t1 = 8.0 * V * math.sqrt(T1 * math.log(16 * m * T1 / p)) / m
        return t0, t1


# --------------------------------------------------------------------------
# State
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SafeguardState:
    """Carried across steps.

    ``A``/``B`` are ``(m, d_pad)`` flat buffers under the flat engine,
    stacked pytrees under the stacked engine, and ``(m, r*k)`` sketch
    matrices in sketched mode.  ``layout`` is static pytree *metadata*
    (``None`` unless the flat engine is active)."""
    good: jax.Array             # (m,) bool — currently-good mask
    step: jax.Array             # () int32
    A: Any                      # long-window accumulator (None in single mode)
    B: Any                      # short-window accumulator
    evicted_at: jax.Array       # (m,) int32, -1 if never evicted (diagnostic)
    layout: Optional[FlatLayout] = None


jax.tree_util.register_dataclass(
    SafeguardState,
    data_fields=("good", "step", "A", "B", "evicted_at"),
    meta_fields=("layout",))


def init_state(cfg: SafeguardConfig, grads_like) -> SafeguardState:
    """``grads_like``: a parameter pytree (NOT stacked) used for shapes."""
    layout = None
    if cfg.use_sketch:
        acc = jnp.zeros((cfg.m, cfg.sketch_reps * cfg.sketch_k), jnp.float32)
        A = acc if cfg.mode == "double" else None
        B = acc
    elif cfg.engine == "flat":
        layout = make_layout(grads_like)
        acc = jnp.zeros((cfg.m, layout.d_padded), cfg.acc_dtype)
        A = acc if cfg.mode == "double" else None
        B = acc
    else:
        def stacked(leaf):
            return jnp.zeros((cfg.m,) + leaf.shape, cfg.acc_dtype)
        acc = jax.tree.map(stacked, grads_like)
        A = acc if cfg.mode == "double" else None
        B = jax.tree.map(stacked, grads_like)
    return SafeguardState(
        good=jnp.ones((cfg.m,), bool),
        step=jnp.zeros((), jnp.int32),
        A=A,
        B=B,
        evicted_at=-jnp.ones((cfg.m,), jnp.int32),
        layout=layout,
    )


# --------------------------------------------------------------------------
# Filter internals
# --------------------------------------------------------------------------

def _empirical_filter(sqdist: jax.Array, good: jax.Array, m: int,
                      scale: float, floor: float):
    """Appendix C.1: score_i = ceil(m/2+1)-th smallest distance over good j;
    med = argmin score;  evict j with d(j, med) >= scale * max(S, floor).

    Returns (pass mask, med index, threshold, scores).
    """
    big = jnp.float32(1e30)
    # decision-site clamp: every sqdist producer clips at 0, but a negative
    # from f32 cancellation slipping through would turn sqrt into NaN and a
    # NaN distance compares False against the threshold — silently evicting
    # honest workers.  Never trust the upstream here.
    dist = jnp.sqrt(jnp.maximum(sqdist, 0.0))
    # mask non-good rows/cols
    dist = jnp.where(good[None, :], dist, big)
    dist = jnp.where(good[:, None], dist, big)
    k = int(-(-m // 2)) + 1        # ceil(m/2) + 1 entries -> index k-1
    k = min(k, m)
    sorted_d = jnp.sort(dist, axis=1)
    scores = sorted_d[:, k - 1]
    scores = jnp.where(good, scores, big)
    med = jnp.argmin(scores)
    S = scores[med]
    thresh = scale * jnp.maximum(S, floor)
    ok = dist[:, med] < thresh
    ok = ok | (jnp.arange(m) == med)
    return ok & good, med, thresh, scores


def _theoretical_filter(sqdist: jax.Array, good: jax.Array, m: int,
                        thresh: float):
    """Paper Algorithm 1 lines 9-11: med = any good i with a strict majority
    of workers within ``thresh``;  evict at ``2 * thresh``."""
    big = jnp.float32(1e30)
    dist = jnp.sqrt(jnp.maximum(sqdist, 0.0))   # see _empirical_filter
    dist = jnp.where(good[None, :], dist, big)
    dist = jnp.where(good[:, None], dist, big)
    within = (dist <= thresh) & good[None, :] & good[:, None]
    counts = within.sum(axis=1)
    valid = good & (counts > m // 2)
    # fall back to max-count worker when the majority event fails
    counts_masked = jnp.where(good, counts, -1)
    med = jnp.where(valid.any(), jnp.argmax(valid), jnp.argmax(counts_masked))
    ok = dist[:, med] <= 2.0 * thresh
    ok = ok | (jnp.arange(m) == med)
    return ok & good, med, jnp.float32(2.0 * thresh), counts.astype(jnp.float32)


def _accumulate_exact(acc, grads, reset, inv_ngood, dtype):
    """acc <- [reset ? 0 : acc] + grads / n_good, in acc dtype."""
    def one(a, g):
        a = jnp.where(reset, jnp.zeros_like(a), a)
        return a + g.astype(dtype) * inv_ngood
    return jax.tree.map(one, acc, grads)


def _accumulate_flat(acc, grads, reset, scale, layout: FlatLayout):
    """acc <- [reset ? 0 : acc] + flatten(grads) * scale, as ONE fused
    in-place chain: the reset ``where`` materializes the new buffer once
    and every per-leaf column-slice add after it updates that buffer in
    place — no intermediate ``(m, d)`` flattened-gradient matrix."""
    buf = jnp.where(reset, jnp.zeros_like(acc), acc)
    leaves = jax.tree_util.tree_leaves(grads)
    m = leaves[0].shape[0]
    for leaf, off in zip(leaves, layout.offsets):
        r = (leaf.astype(jnp.float32).reshape(m, -1)
             * scale).astype(acc.dtype)
        buf = buf.at[:, off:off + r.shape[1]].add(r)
    return buf


def _flat_sqdist(buf, cfg: SafeguardConfig):
    """Pairwise squared distances of the flat accumulator: blocked Pallas
    Gram kernel (one block under the CPU interpreter) or a single XLA
    ``dot_general`` (shardable: worker rows stay on their data shards and
    only the (m, m) output is combined)."""
    if cfg.backend == "pallas":
        from repro.kernels.safeguard_filter import pairwise_sqdist
        return pairwise_sqdist(buf, block_d=None, interpret=not _on_tpu())
    from repro.kernels.safeguard_filter import ref as sf_ref
    return sf_ref.pairwise_sqdist(buf)


def _flat_update(acc, grads, gflat, reset, scale, cfg: SafeguardConfig,
                 layout: FlatLayout):
    """One accumulator's flat-engine update -> (new_acc, sqdist).

    ``gflat`` is the flattened gradient matrix, materialized by the caller
    only for the ``pallas_fused`` backend (``None`` otherwise)."""
    if gflat is not None:
        from repro.kernels.safeguard_filter import fused_accumulate_sqdist
        return fused_accumulate_sqdist(acc, gflat, reset, scale,
                                       interpret=not _on_tpu())
    new = _accumulate_flat(acc, grads, reset, scale, layout)
    return new, _flat_sqdist(new, cfg)


# --------------------------------------------------------------------------
# The step
# --------------------------------------------------------------------------

def safeguard_step(state: SafeguardState, grads, cfg: SafeguardConfig,
                   rng: Optional[jax.Array] = None, *,
                   acc_sharding=None):
    """One master-side safeguard step.

    Args:
      state:  SafeguardState.
      grads:  stacked per-worker gradient pytree, leaves ``(m, ...)``.  The
        Byzantine simulation (attacks) has already been applied.
      cfg:    SafeguardConfig.
      rng:    PRNG key for the Gaussian perturbation (required if nu > 0).
      acc_sharding: optional ``NamedSharding`` pinned onto the flat gradient
        buffer (and hence the accumulators) so the worker rows stay on the
        ``data`` mesh axes under a sharded jit (DESIGN.md §3).

    Returns:
      (new_state, aggregated gradient pytree, info dict)
    """
    m = cfg.m
    t = state.step
    good = state.good

    # Section 5 relaxation: periodically restore every worker.  A restored
    # worker's ``evicted_at`` diagnostic is cleared too — otherwise the
    # post-reset eviction times (fig2b trace) would keep reporting the
    # pre-reset eviction forever.
    restored = jnp.zeros_like(good)
    evicted_at = state.evicted_at
    if cfg.reset_period > 0:
        restore = (t % cfg.reset_period) == 0
        restored = restore & ~good
        good = jnp.where(restore, jnp.ones_like(good), good)
        evicted_at = jnp.where(restored, -1, evicted_at)

    n_good = jnp.maximum(good.sum(), 1).astype(jnp.float32)
    inv_ngood = 1.0 / n_good

    reset_B = (t % cfg.T0) == 0
    reset_A = (t % cfg.T1) == 0

    if cfg.use_sketch:
        gsk = sk.sketch_tree(grads, k=cfg.sketch_k, reps=cfg.sketch_reps,
                             seed=cfg.sketch_seed)
        B = jnp.where(reset_B, 0.0, state.B) + gsk * inv_ngood
        A = None
        if cfg.mode == "double":
            A = jnp.where(reset_A, 0.0, state.A) + gsk * inv_ngood
        sqdist_B = sk.sketch_pairwise_sqdist(B)
        sqdist_A = sk.sketch_pairwise_sqdist(A) if A is not None else None
    elif cfg.engine == "flat":
        layout = state.layout
        use_fused = (cfg.backend == "pallas_fused"
                     and jnp.dtype(cfg.acc_dtype) == jnp.float32)
        gflat = flatten_stacked(grads, layout) if use_fused else None
        B, sqdist_B = _flat_update(state.B, grads, gflat, reset_B,
                                   inv_ngood, cfg, layout)
        A, sqdist_A = None, None
        if cfg.mode == "double":
            A, sqdist_A = _flat_update(state.A, grads, gflat, reset_A,
                                       inv_ngood, cfg, layout)
        if acc_sharding is not None:
            B = jax.lax.with_sharding_constraint(B, acc_sharding)
            if A is not None:
                A = jax.lax.with_sharding_constraint(A, acc_sharding)
    else:
        B = _accumulate_exact(state.B, grads, reset_B, inv_ngood,
                              cfg.acc_dtype)
        A = None
        if cfg.mode == "double":
            A = _accumulate_exact(state.A, grads, reset_A, inv_ngood,
                                  cfg.acc_dtype)
        sqdist_B = tu.tree_pairwise_sqdist(B)
        sqdist_A = tu.tree_pairwise_sqdist(A) if A is not None else None

    if cfg.rule == "empirical":
        okB, medB, thB, scoresB = _empirical_filter(
            sqdist_B, good, m, cfg.threshold_scale, cfg.threshold_floor)
        if cfg.mode == "double":
            okA, medA, thA, _ = _empirical_filter(
                sqdist_A, good, m, cfg.threshold_scale, cfg.threshold_floor)
        else:
            okA, medA, thA = jnp.ones_like(okB), medB, thB
    else:
        okB, medB, thB, scoresB = _theoretical_filter(
            sqdist_B, good, m, cfg.thresh0)
        if cfg.mode == "double":
            okA, medA, thA, _ = _theoretical_filter(
                sqdist_A, good, m, cfg.thresh1)
        else:
            okA, medA, thA = jnp.ones_like(okB), medB, thB

    new_good = good & okA & okB

    newly_evicted = good & ~new_good
    evicted_at = jnp.where(newly_evicted, t, evicted_at)

    # SGD direction over good_t (pre-filter, paper line 12) or good_{t+1}.
    agg_mask = good if cfg.aggregate_prefilter else new_good
    agg = tu.tree_masked_mean(grads, agg_mask)

    if cfg.nu > 0.0:
        if rng is None:
            raise ValueError("nu > 0 requires an rng key")
        keys = jax.random.split(rng, len(jax.tree_util.tree_leaves(agg)))
        keys = iter(list(keys))

        def add_noise(leaf):
            k = next(keys)
            return leaf + cfg.nu * jax.random.normal(k, leaf.shape, leaf.dtype)
        agg = jax.tree.map(add_noise, agg)

    new_state = SafeguardState(
        good=new_good,
        step=t + 1,
        A=A if cfg.mode == "double" else state.A,
        B=B,
        evicted_at=evicted_at,
        layout=state.layout,
    )
    dist_B = jnp.sqrt(jnp.maximum(sqdist_B, 0.0))[:, medB]
    dist_A = (jnp.sqrt(jnp.maximum(sqdist_A, 0.0))[:, medA]
              if sqdist_A is not None else dist_B)
    info = {
        "n_good": n_good,
        "med_B": medB,
        "med_A": medA,
        "threshold_B": thB,
        "threshold_A": thA,
        "dist_to_med_B": dist_B,
        "dist_to_med_A": dist_A,
        "scores_B": scoresB,
        "newly_evicted": newly_evicted,
        "restored": restored,
        "good": new_good,
    }
    return new_state, agg, info
