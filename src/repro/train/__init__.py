from repro.train.trainer import (   # noqa: F401
    TrainState, init_train_state, make_train_step, scan_trial, Trainer,
    zeno_scores)
from repro.train.serve import generate   # noqa: F401
