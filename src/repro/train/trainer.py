"""Byzantine-resilient training loop.

``make_train_step`` builds one jitted step implementing the paper's
master/worker protocol in SPMD form:

  1. per-worker gradients — ``vmap`` of ``value_and_grad`` over the worker
     axis of the batch (leaves (m, B/m, ...)); under the production mesh
     the worker axis is sharded over ``data`` so each data shard computes
     exactly one worker's gradient (DESIGN.md §3);
  2. the Byzantine simulation — an attack from ``core.attacks`` rewrites
     the rows of the stacked gradient marked by ``byz_mask``; adaptive
     attacks additionally ``observe`` the defense's public outputs of the
     previous step (good mask, thresholds, median distances — DESIGN.md
     §11), threaded through ``TrainState.attack_state`` so the feedback
     loop survives ``scan_trial``/vmap;
  3. aggregation — ONE ``core.defenses.Defense`` object (DESIGN.md §12):
     SafeguardSGD, a historyless baseline, or a history-aware zoo
     defense (centered clipping, norm filter, DnC, compositions).  Its
     state — the safeguard's flat ``(m, d_pad)`` accumulators, momentum
     buffers, EMA scalars — is threaded through
     ``TrainState.defense_state``; flat buffers keep their worker rows
     pinned to the ``data`` mesh axes via ``acc_sharding``, so windowed
     accumulates stay shard-local and only the ``(m, m)`` distance
     matrix crosses shards;
  4. the optimizer update.

``Trainer`` wraps the step with a plain python loop, metric collection and
checkpointing for the benchmarks/examples.  ``scan_trial`` rolls an entire
trial (data generation + step) into one ``lax.scan`` so a full training
run is a single device program — the campaign engine
(``repro.campaign.engine``) builds on it to ``vmap`` whole trials over
seeds and scenario knobs.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregators as agg_lib
from repro.core import attacks as atk_lib
from repro.core import defenses as dfn_lib
from repro.core import safeguard as sg
from repro.core import tree_utils as tu
from repro.data import hetero as het_lib
from repro.obs import schema as obs_schema
from repro.optim import OptimizerBundle

f32 = jnp.float32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    defense_state: Any
    attack_state: Any
    step: jax.Array
    rng: jax.Array

    @property
    def sg_state(self):
        """Back-compat alias from the pre-protocol era, when the
        safeguard was the only stateful defense."""
        return self.defense_state


def resolve_defense(defense: Optional[dfn_lib.Defense] = None,
                    sg_cfg: Optional[sg.SafeguardConfig] = None,
                    aggregator: Optional[agg_lib.Aggregator] = None
                    ) -> dfn_lib.Defense:
    """One :class:`core.defenses.Defense` from the new (``defense=``) or
    legacy (``sg_cfg=`` / ``aggregator=``) spellings."""
    if defense is not None:
        if sg_cfg is not None or aggregator is not None:
            raise ValueError("pass either defense or sg_cfg/aggregator, "
                             "not both")
        return defense
    if (sg_cfg is None) == (aggregator is None):
        raise ValueError("pass exactly one of sg_cfg / aggregator")
    if sg_cfg is not None:
        return dfn_lib.make_safeguard_defense(sg_cfg)
    return dfn_lib.from_aggregator(aggregator)


def init_train_state(params, opt: OptimizerBundle, *,
                     defense: Optional[dfn_lib.Defense] = None,
                     sg_cfg: Optional[sg.SafeguardConfig] = None,
                     aggregator: Optional[agg_lib.Aggregator] = None,
                     attack: Optional[atk_lib.Attack] = None,
                     seed: int = 0) -> TrainState:
    defense_state = None
    if defense is not None or sg_cfg is not None:
        d = resolve_defense(defense, sg_cfg, aggregator)
        if d.init_state is not None:
            defense_state = d.init_state(params)
    attack_state = (attack.init(params)
                    if attack is not None and attack.init is not None
                    else None)
    return TrainState(params=params, opt_state=opt.init(params),
                      defense_state=defense_state,
                      attack_state=attack_state,
                      step=jnp.zeros((), jnp.int32),
                      rng=jax.random.PRNGKey(seed))


def zeno_scores(loss_fn, params, grads, held_batch, *, eta: float,
                rho: float):
    """Zeno's stochastic descendant score per worker (Definition C.4):
    Score(g_i) = f_r(x) - f_r(x - eta g_i) - rho ||g_i||^2 evaluated on a
    held-out minibatch (the master-side oracle)."""
    loss_before = loss_fn(params, held_batch)

    def one(g_row):
        stepped = jax.tree.map(
            lambda p, g: (p.astype(f32) - eta * g.astype(f32)
                          ).astype(p.dtype), params, g_row)
        return loss_fn(stepped, held_batch)

    loss_after = jax.vmap(one)(grads)
    # per-row squared norms (O(m d)) — NOT the full (m, m) Gram, whose
    # only consumed entries would be its diagonal
    sq = tu.tree_row_sq_norms(grads)
    return loss_before - loss_after - rho * sq


def make_train_step(loss_fn: Callable, opt: OptimizerBundle, *,
                    byz_mask: jax.Array,
                    defense: Optional[dfn_lib.Defense] = None,
                    sg_cfg: Optional[sg.SafeguardConfig] = None,
                    aggregator: Optional[agg_lib.Aggregator] = None,
                    attack: Optional[atk_lib.Attack] = None,
                    zeno_eta: float = 0.1, zeno_rho: float = 5e-4,
                    spmd_axis_name=None, acc_sharding=None,
                    sg_acc_sharding=None, trace_zeta: bool = True,
                    perturb: str = "none", escape_nu=0.0,
                    escape_thresh=0.1,
                    so_probe: Optional[Callable] = None,
                    jit: bool = True):
    """Build the jitted training step.

    The defense is one :class:`core.defenses.Defense` (``defense=``);
    the legacy spellings ``sg_cfg=`` (the paper's safeguard) and
    ``aggregator=`` (a historyless baseline) are resolved through the
    same protocol.  ``loss_fn(params, worker_batch) -> scalar``.

    ``spmd_axis_name``: mesh axis (or tuple) carrying the worker dimension
    at scale — passed to ``vmap`` so every per-worker intermediate keeps
    its data-axis sharding through the backward pass (without it XLA's
    propagation drops the worker sharding inside the layer scan and
    replicates multi-GiB attention buffers).

    ``acc_sharding``: optional ``NamedSharding`` for the defense's flat
    ``(m, d_pad)`` state buffers (see ``launch.sharding.flat_acc_pspec``);
    ``None`` on a single device.  ``sg_acc_sharding`` is the deprecated
    alias.

    ``perturb="sgd_escape"`` enables the paper's saddle-escape
    perturbation (DESIGN.md §14): when the aggregated direction's norm
    falls to ``escape_thresh`` or below — the master's observable proxy
    for "near a stationary point" — isotropic ``N(0, escape_nu^2 I)``
    noise is added to it.  Injected *after* aggregation, so Byzantine
    workers can only react to the draw one step late.  ``escape_nu`` /
    ``escape_thresh`` may be traced scalars (campaign vmap knobs); the
    mode itself is program structure (it consumes an extra rng split).

    ``so_probe``: optional pure function ``params -> {name: scalar}``
    traced into the metrics every step — the second-order trace lane of
    the planted-saddle testbed (``data.saddle.make_probe``: the analytic
    ``true_grad_norm`` / ``min_eig_proxy`` / ``escaped``).
    """
    defense = resolve_defense(defense, sg_cfg, aggregator)
    if acc_sharding is None:
        acc_sharding = sg_acc_sharding
    attack = attack or atk_lib.Attack("none", atk_lib.attack_none)
    if perturb not in ("none", "sgd_escape"):
        raise ValueError(f"unknown perturbation mode {perturb!r} "
                         "(one of 'none', 'sgd_escape')")
    m = int(byz_mask.shape[0])

    def step_fn(state: TrainState, batch, held_batch=None):
        if perturb == "sgd_escape":
            rng, k_attack, k_noise, k_escape = jax.random.split(state.rng, 4)
        else:
            rng, k_attack, k_noise = jax.random.split(state.rng, 3)

        # (1) per-worker gradients
        vg = jax.value_and_grad(loss_fn)
        losses, grads = jax.vmap(lambda wb: vg(state.params, wb),
                                 spmd_axis_name=spmd_axis_name)(batch)

        # (2) Byzantine simulation — the attack state already absorbed the
        # previous step's public defense feedback (observe, below)
        grads, attack_state = attack.act(grads, byz_mask, state.attack_state,
                                         state.step, k_attack)

        # (3) aggregation through the Defense protocol (DESIGN.md §12)
        metrics: Dict[str, jax.Array] = {
            "loss": losses.mean(),
            "honest_loss": (losses * (~byz_mask)).sum()
            / jnp.maximum((~byz_mask).sum(), 1),
        }
        ctx = {"rng": k_noise, "acc_sharding": acc_sharding}
        if defense.needs_held_batch:
            if held_batch is None:
                raise ValueError(f"{defense.name} needs a held-out batch")
            ctx["scores"] = zeno_scores(loss_fn, state.params, grads,
                                        held_batch, eta=zeno_eta,
                                        rho=zeno_rho)
        agg, defense_state, info = defense.aggregate(state.defense_state,
                                                     grads, ctx)
        # flight-recorder schema check (DESIGN.md §15): tracer shapes and
        # dtypes are static, so this runs once per program trace and is
        # free per step — a defense renaming a key or changing a shape
        # class fails loudly here instead of corrupting campaign traces
        obs_schema.validate_info(info, m, where=f"defense:{defense.name}")
        # dissimilarity-aware trace layer (DESIGN.md §13): the measured
        # zeta^2 heterogeneity of the reported gradients — over the
        # simulation's ground-truth honest set and over the defense's
        # live good set (what a real master could compute).  Two O(m d)
        # passes; ``trace_zeta=False`` drops them from the hot path
        # (the at-scale lowering of launch/specs does)
        if trace_zeta:
            metrics["zeta_sq"] = het_lib.zeta_sq(grads, ~byz_mask)
            metrics["zeta_good_sq"] = het_lib.zeta_sq(grads, info["good"])
        if defense.stateful:
            metrics["n_good"] = info["n_good"]
            metrics["caught_byz"] = (byz_mask & ~info["good"]).sum()
            metrics["evicted_honest"] = (~byz_mask & ~info["good"]).sum()
            metrics["good"] = info["good"]
            if "restored" in info:
                metrics["restored"] = info["restored"].sum()
        # per-worker detection statistics + live thresholds, traced when
        # the defense publishes them — the obs event layer reconstructs
        # evictions/threshold-crossings from exactly these surfaces
        # (Fig-2a reads them from the engine's traces instead of
        # re-implementing the training loop)
        for k in ("dist_to_med_B", "dist_to_med_A",
                  "threshold_B", "threshold_A"):
            if k in info:
                metrics[k] = jnp.asarray(info[k], jnp.float32)
        # adaptive-attack controller level consumed by this step's act()
        # (observe has not folded this step's feedback yet) — its
        # reversals are the attack's phase boundaries
        if attack.observe is not None:
            lvl = atk_lib.controller_level(state.attack_state)
            if lvl is not None:
                metrics["attack_level"] = lvl
        # second-order trace lane (DESIGN.md §14): analytic saddle
        # diagnostics of the current iterate, traced like zeta_sq
        if so_probe is not None:
            metrics.update(so_probe(state.params))
        # the paper's saddle-escape perturbation: isotropic noise on the
        # aggregated direction when its norm says "near-stationary"
        if perturb == "sgd_escape":
            agg_norm = jnp.sqrt(tu.tree_sq_norm(agg))
            on = (agg_norm <= jnp.asarray(escape_thresh, f32)).astype(f32)
            leaves = jax.tree_util.tree_leaves(agg)
            keys = iter(list(jax.random.split(k_escape, len(leaves))))

            def _noise(leaf):
                k = next(keys)
                xi = jax.random.normal(k, leaf.shape, f32)
                return (leaf.astype(f32)
                        + on * jnp.asarray(escape_nu, f32) * xi
                        ).astype(leaf.dtype)
            agg = jax.tree.map(_noise, agg)
            metrics["escape_on"] = on
        feedback = atk_lib.defense_feedback(info, m)

        # feedback coupling (DESIGN.md §11): adaptive attacks fold this
        # step's public defense outputs into the state the next step's
        # act() will read — the carry keeps the loop scan/vmap-able
        if attack.observe is not None:
            attack_state = attack.observe(attack_state, feedback, byz_mask)

        # (4) optimizer
        params, opt_state = opt.update(agg, state.opt_state, state.params,
                                       state.step)
        metrics["grad_norm"] = jnp.sqrt(tu.tree_sq_norm(agg))
        obs_schema.validate_metrics(metrics, m,
                                    where=f"train_step:{defense.name}")
        new_state = TrainState(params=params, opt_state=opt_state,
                               defense_state=defense_state,
                               attack_state=attack_state,
                               step=state.step + 1, rng=rng)
        return new_state, metrics

    return jax.jit(step_fn) if jit else step_fn


def _select_traces(metrics: Dict, trace_fields) -> Dict:
    if trace_fields is None:
        return metrics
    unknown = [k for k in trace_fields if k not in metrics]
    if unknown:
        raise ValueError(
            f"scan_trial: unknown trace field(s) {unknown}; this "
            f"step emits {sorted(metrics)}")
    return {k: metrics[k] for k in trace_fields}


def tap_payload(metrics: Dict, state: TrainState,
                tap_meta: Optional[Dict] = None) -> Dict:
    """Reduce a ``(K, ...)``-stacked window of step metrics to the
    bounded scalar payload of one heartbeat (the tap surface of
    ``repro.obs.schema``): window ``mean`` for loss-like keys, window
    ``last`` for live state, ``tap_meta`` scalars (lane identity)
    merged in verbatim.  Pure; runs inside the outer scan body."""
    payload: Dict[str, jax.Array] = {
        "step": jnp.asarray(state.step, jnp.int32)}
    for name in obs_schema.DEVICE_TAP_KEYS:
        spec = obs_schema.TAP[name]
        if name == "step" or name not in metrics:
            continue
        col = metrics[name]
        val = col.mean() if spec.agg == "mean" else col[-1]
        payload[name] = jnp.asarray(val, spec.dtype)
    if tap_meta:
        for name, val in tap_meta.items():
            payload[name] = jnp.asarray(val)
    return obs_schema.validate_tap(payload, where="scan_trial.tap")


def scan_trial(step_fn, state: TrainState, *, batch_fn, steps: int,
               held_fn=None, trace_fields=None, tap_every: int = 0,
               tap: Optional[Callable] = None, tap_meta=None):
    """Roll a whole training trial into one ``lax.scan``.

    ``step_fn`` must be the *unjitted* step (``make_train_step(...,
    jit=False)``) — its carry (:class:`TrainState`) already threads the
    optimizer, defense and attack state pytrees, which is exactly what
    makes the loop body scan-able (and, one level up, vmap-able over
    seeds/scenario knobs).

    ``batch_fn(t) -> worker batch`` and ``held_fn(t) -> held-out batch``
    regenerate the data *inside* the scan body from the step index — they
    must be pure jax functions (the seeded synthetic pipelines in
    ``repro.data`` are; see ``teacher_batches``'s fold_in scheme).

    ``trace_fields``: optional subset of metric names to stack over the
    step axis (default: all metrics the step emits).  ``()`` traces
    nothing (the scan carries no ys, so trace memory is zero); a name the
    step does not emit raises :class:`ValueError` at trace time, naming
    both the offender and the available fields.

    ``tap_every=K`` with a host callable ``tap`` streams a bounded
    scalar summary of every K-step window (:func:`tap_payload`, typed by
    ``repro.obs.schema.TAP``) through ``jax.experimental.io_callback``
    — the live-telemetry layer (DESIGN.md §17).  The scan is then
    nested: an outer scan over ``steps // K`` windows whose body is an
    inner scan over K steps plus one unconditional callback.  The
    nesting is what keeps the callback legal under the campaign
    engine's vmap (``io_callback`` under ``vmap``-of-``cond`` is
    unsupported) and changes **nothing** about the computation: the
    step sequence, rng stream and stacked traces are bit-identical to
    the flat scan (locked by tests/test_live.py).  ``steps`` must be a
    multiple of K.  Under vmap the callback fires once per lane per
    window with unbatched scalars and no lane identity — thread one
    through ``tap_meta`` (a dict of traced scalars merged into every
    payload, e.g. ``{"lane": knobs["lane"]}``).  ``tap_every=0``
    (default) is byte-for-byte the untapped program.

    Returns ``(final_state, traces)`` with each trace leaf shaped
    ``(steps, ...)``.
    """
    def body(st, t, _keep=trace_fields):
        batch = batch_fn(t)
        if held_fn is not None:
            st, metrics = step_fn(st, batch, held_fn(t))
        else:
            st, metrics = step_fn(st, batch)
        return st, _select_traces(metrics, _keep)

    if not tap_every:
        return jax.lax.scan(body, state, jnp.arange(steps))

    if tap is None:
        raise ValueError("scan_trial: tap_every > 0 needs a host `tap` "
                         "callable (see repro.obs.live.LiveCollector)")
    K = int(tap_every)
    if K < 0 or steps % K != 0:
        raise ValueError(
            f"scan_trial: steps ({steps}) must be a positive multiple of "
            f"tap_every ({K}) — windows must tile the trial exactly so "
            "the tapped step sequence is the untapped one")
    from jax.experimental import io_callback

    def window(st, ts):
        # full metrics as inner ys (the payload may need keys outside
        # trace_fields); filtered down before they reach the outer ys
        st, mets = jax.lax.scan(lambda s, t: body(s, t, _keep=None),
                                st, ts)
        payload = tap_payload(mets, st, tap_meta)
        io_callback(tap, None, payload)
        return st, _select_traces(mets, trace_fields)

    final, traces = jax.lax.scan(window, state,
                                 jnp.arange(steps).reshape(steps // K, K))
    traces = jax.tree.map(
        lambda a: a.reshape((steps,) + tuple(a.shape[2:])), traces)
    return final, traces


class Trainer:
    """Python-loop wrapper: data iterators, metrics history, eval hooks.

    Interactive logging goes through the same live-telemetry path as
    campaign cells (``repro.obs.live.LiveCollector``, DESIGN.md §17):
    at every ``log_every`` boundary the scalar record's tap-surface
    subset becomes one heartbeat — ring-buffered, optionally persisted
    (pass a ``collector`` with a ``heartbeat_dir``), and echoed to the
    terminal when ``verbose``.  Scalar ``history`` is unchanged by any
    of this."""

    def __init__(self, state: TrainState, step_fn, data_iter, *,
                 held_iter=None, eval_fn: Optional[Callable] = None,
                 log_every: int = 50, name: str = "run", collector=None):
        self.state = state
        self.step_fn = step_fn
        self.data_iter = data_iter
        self.held_iter = held_iter
        self.eval_fn = eval_fn
        self.log_every = log_every
        self.name = name
        self.collector = collector
        self.history: list = []
        # non-scalar metrics are trace material, not history lines: they
        # accumulate here every step (as device arrays — no host sync)
        # and trace_arrays() stacks them, matching scan_trial's layout
        self.traces: Dict[str, list] = {}
        self._routed_keys: set = set()

    def trace_arrays(self) -> Dict[str, "np.ndarray"]:
        """Stack the accumulated per-step vector metrics into
        ``(steps, ...)`` numpy arrays — the same dense-trace layout
        ``scan_trial`` returns, consumable by ``repro.obs.events``."""
        return {k: np.stack([np.asarray(v) for v in vs])
                for k, vs in self.traces.items()}

    def run(self, steps: int, verbose: bool = True):
        collector = self.collector
        if collector is None and verbose:
            from repro.obs import live as live_lib
            collector = self.collector = live_lib.LiveCollector(
                name=self.name, echo=print)
        t0 = time.time()
        for i in range(steps):
            batch = next(self.data_iter)
            if self.held_iter is not None:
                held = next(self.held_iter)
                self.state, metrics = self.step_fn(self.state, batch, held)
            else:
                self.state, metrics = self.step_fn(self.state, batch)
            # route non-scalar metrics to the trace path (history holds
            # scalars only); surface what was routed once per run so the
            # keys are not silently invisible
            vec = {k: v for k, v in metrics.items()
                   if getattr(v, "ndim", 0) != 0}
            for k, v in vec.items():
                self.traces.setdefault(k, []).append(v)
            new_keys = set(vec) - self._routed_keys
            if new_keys:
                self._routed_keys |= new_keys
                if verbose:
                    print(f"[{self.name}] non-scalar metrics routed to "
                          f".traces (not history): {sorted(new_keys)}")
            if (i + 1) % self.log_every == 0 or i == steps - 1:
                rec = {k: float(v) for k, v in metrics.items()
                       if getattr(v, "ndim", 0) == 0}
                rec["step"] = int(self.state.step)
                if self.eval_fn is not None:
                    rec.update(self.eval_fn(self.state.params))
                rec["wall_s"] = time.time() - t0
                self.history.append(rec)
                # one telemetry path for interactive runs and campaign
                # cells: the record's tap-surface subset is a heartbeat
                # (the collector stamps step_rate/t_wall and echoes it)
                if collector is not None:
                    collector.tap({k: v for k, v in rec.items()
                                   if k in obs_schema.TAP})
        return self.history
