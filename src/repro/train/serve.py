"""Batched autoregressive serving on top of prefill/decode.

The Byzantine layer does not apply at inference; this module provides the
end-to-end decode driver used by the serving example and the decode-shape
dry runs.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T


@functools.partial(jax.jit, static_argnames=("cfg", "n_tokens", "max_seq",
                                             "temperature"))
def generate(params, cfg: ModelConfig, prompt, *, n_tokens: int,
             max_seq: int, rng: Optional[jax.Array] = None,
             temperature: float = 0.0):
    """Greedy (or temperature-sampled) generation.

    prompt: (B, Lp) int32 tokens (or (B, Lp, d) embeddings for stub
    frontends — generated tokens are then fed back through the LM head's
    embedding-free path, so stub archs decode token ids only if the config
    has an ``embed`` table; MusicGen-style serving feeds codec frames).
    Returns (B, n_tokens) int32.
    """
    if cfg.embed_stub and prompt.ndim == 2:
        raise ValueError("stub-frontend archs need embedding prompts")
    B = prompt.shape[0]
    last_logits, cache = T.prefill(params, cfg, prompt, max_seq=max_seq)
    rng = rng if rng is not None else jax.random.PRNGKey(0)

    def sample(logits, key):
        if temperature > 0.0:
            return jax.random.categorical(key, logits / temperature)
        return logits.argmax(-1)

    def body(carry, _):
        logits, cache, key = carry
        key, k1 = jax.random.split(key)
        tok = sample(logits, k1).astype(jnp.int32)      # (B,)
        if cfg.embed_stub:
            # feed generated codec/text token back via the output head's
            # transpose as a pseudo-embedding (stub frontends have no
            # token table; this matches the dry-run serving path)
            emb = params["lm_head"].T[tok][:, None, :].astype(cfg.dtype)
            logits_next, cache = T.decode_step(params, cfg, emb, cache)
        else:
            logits_next, cache = T.decode_step(params, cfg, tok[:, None],
                                               cache)
        return (logits_next, cache, key), tok

    (_, _, _), toks = jax.lax.scan(body, (last_logits, cache, rng), None,
                                   length=n_tokens)
    return toks.T                                       # (B, n_tokens)
