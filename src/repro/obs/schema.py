"""Typed metric schema / registry — layer 1 of the flight recorder
(DESIGN.md §15).

Every per-step statistic this repo emits crosses one of two surfaces:

  * the **metric surface** — the dict ``train.trainer.make_train_step``
    returns each step (and ``scan_trial`` stacks into traces);
  * the **info surface** — the dict every ``Defense.aggregate``
    publishes (the public outputs adaptive attacks observe and the
    trainer re-traces).

Before this layer both were untyped: a defense could rename a key, emit
an ``(m,)`` array where a scalar was expected, or silently change dtype,
and nothing would notice until a campaign JSONL stopped lining up with
an older one.  The registry below gives each name a :class:`MetricSpec`
(canonical dtype, shape class, source, guard-window tag) and the
``validate_*`` entry points enforce it **at trace time** — shapes and
dtypes of jax tracers are static, so validation runs once per program
trace and costs nothing per step.

PR 10 adds a third surface:

  * the **tap surface** — the bounded per-window summary
    ``scan_trial(tap_every=K)`` streams out of the running scan through
    ``jax.experimental.io_callback`` (``repro.obs.live``); every tap key
    is a *scalar* (the payload must stay bounded regardless of model
    size), and its ``agg`` field records how the window of per-step
    values is reduced to one number (``mean`` over the window or
    ``last`` value), so a heartbeat line is interpretable without the
    producing program.

Shape classes:

  ``scalar``       shape ``()``
  ``per_worker``   shape ``(m,)`` — one entry per simulated worker row
  ``per_window``   shape ``()``, tagged with the safeguard guard window
                   (``B`` = inner/T0, ``A`` = outer/T1) the statistic
                   belongs to; per-window *vectors* (``dist_to_med_B``)
                   are ``per_worker`` with a window tag
  ``per_bucket``   1-D with length dividing ``m`` — the bucketing
                   meta-defense's bucket axis (``m / bucket_s`` rows)

Dtype validation is by *kind* (floating / integer / bool): the canonical
dtype in the spec is what the CPU protocol produces (and what the
``.npz`` trace sidecars store), but an at-scale bf16 loss is the same
metric.  A shape-class violation or an unregistered name raises
:class:`SchemaError` naming the key — extend with
:func:`register_metric` (e.g. for a custom ``so_probe``) instead of
silencing."""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional

import numpy as np

SCALAR = "scalar"
PER_WORKER = "per_worker"
PER_WINDOW = "per_window"
PER_BUCKET = "per_bucket"
SHAPE_CLASSES = (SCALAR, PER_WORKER, PER_WINDOW, PER_BUCKET)

# surfaces a spec may be registered on
METRIC_SURFACE = "metrics"
INFO_SURFACE = "info"
TAP_SURFACE = "tap"

# window-reduction modes a tap key may declare
TAP_AGGS = ("mean", "last", "host")


class SchemaError(ValueError):
    """A metric/info dict violated the typed schema (unknown name, wrong
    shape class, wrong dtype kind)."""


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One registered statistic.

    ``dtype`` is the canonical dtype name (validation is by kind);
    ``window`` tags the safeguard guard window (``"B"``/``"A"``) for
    per-window statistics; ``source`` names the layer that emits it."""
    name: str
    dtype: str                      # canonical: float32 | int32 | bool
    shape_class: str                # one of SHAPE_CLASSES
    source: str                     # trainer | defense | probe | attack
    description: str = ""
    window: Optional[str] = None    # "B" | "A" for guard-window stats
    agg: Optional[str] = None       # tap surface: mean | last | host

    def __post_init__(self):
        if self.shape_class not in SHAPE_CLASSES:
            raise ValueError(f"unknown shape class {self.shape_class!r} "
                             f"(one of {SHAPE_CLASSES})")
        if self.agg is not None and self.agg not in TAP_AGGS:
            raise ValueError(f"unknown tap agg {self.agg!r} "
                             f"(one of {TAP_AGGS})")


def _spec_table(specs: Iterable[MetricSpec]) -> Dict[str, MetricSpec]:
    return {s.name: s for s in specs}


# --------------------------------------------------------------------------
# The info surface: every key any Defense.aggregate may publish
# --------------------------------------------------------------------------

INFO: Dict[str, MetricSpec] = _spec_table([
    MetricSpec("good", "bool", PER_WORKER, "defense",
               "membership mask aggregated over this step"),
    MetricSpec("n_good", "float32", SCALAR, "defense",
               "live good-set size"),
    MetricSpec("med_B", "int32", PER_WINDOW, "defense",
               "concentration-median worker index, inner window",
               window="B"),
    MetricSpec("med_A", "int32", PER_WINDOW, "defense",
               "concentration-median worker index, outer window",
               window="A"),
    MetricSpec("threshold_B", "float32", PER_WINDOW, "defense",
               "live eviction threshold, inner (T0) guard", window="B"),
    MetricSpec("threshold_A", "float32", PER_WINDOW, "defense",
               "live eviction threshold, outer (T1) guard", window="A"),
    MetricSpec("dist_to_med_B", "float32", PER_WORKER, "defense",
               "per-worker accumulator distance to the inner-window "
               "median", window="B"),
    MetricSpec("dist_to_med_A", "float32", PER_WORKER, "defense",
               "per-worker accumulator distance to the outer-window "
               "median", window="A"),
    MetricSpec("scores_B", "float32", PER_WORKER, "defense",
               "Appendix C.1 concentration scores, inner window",
               window="B"),
    MetricSpec("newly_evicted", "bool", PER_WORKER, "defense",
               "workers evicted by exactly this step's filter"),
    MetricSpec("restored", "bool", PER_WORKER, "defense",
               "workers readmitted by this step's periodic reset"),
    MetricSpec("clip_center_norm", "float32", SCALAR, "defense",
               "centered-clipping aggregate norm"),
    MetricSpec("norm_ema", "float32", SCALAR, "defense",
               "norm_filter's EMA of the median reported norm"),
    MetricSpec("spectral_scores", "float32", PER_WORKER, "defense",
               "DnC squared projection onto the top singular direction"),
    MetricSpec("bucket_good", "bool", PER_BUCKET, "defense",
               "bucketing meta-defense: per-bucket inner decision"),
])

# --------------------------------------------------------------------------
# The metric surface: every key make_train_step may emit
# --------------------------------------------------------------------------

METRICS: Dict[str, MetricSpec] = _spec_table([
    MetricSpec("loss", "float32", SCALAR, "trainer",
               "mean per-worker training loss (attacked rows included)"),
    MetricSpec("honest_loss", "float32", SCALAR, "trainer",
               "mean training loss over honest workers"),
    MetricSpec("zeta_sq", "float32", SCALAR, "trainer",
               "measured gradient dissimilarity over the ground-truth "
               "honest set (DESIGN.md §13)"),
    MetricSpec("zeta_good_sq", "float32", SCALAR, "trainer",
               "measured dissimilarity over the defense's live good set"),
    MetricSpec("n_good", "float32", SCALAR, "trainer",
               "live good-set size (re-traced from the defense info)"),
    MetricSpec("caught_byz", "int32", SCALAR, "trainer",
               "Byzantine workers outside the current good set"),
    MetricSpec("evicted_honest", "int32", SCALAR, "trainer",
               "honest workers outside the current good set"),
    MetricSpec("restored", "int32", SCALAR, "trainer",
               "workers readmitted by this step's periodic reset"),
    MetricSpec("good", "bool", PER_WORKER, "trainer",
               "post-decision membership mask (the event layer derives "
               "evictions/restorations from its transitions)"),
    MetricSpec("dist_to_med_B", "float32", PER_WORKER, "trainer",
               "per-worker distance to the inner-window median",
               window="B"),
    MetricSpec("dist_to_med_A", "float32", PER_WORKER, "trainer",
               "per-worker distance to the outer-window median",
               window="A"),
    MetricSpec("threshold_B", "float32", PER_WINDOW, "trainer",
               "live eviction threshold, inner (T0) guard", window="B"),
    MetricSpec("threshold_A", "float32", PER_WINDOW, "trainer",
               "live eviction threshold, outer (T1) guard", window="A"),
    MetricSpec("grad_norm", "float32", SCALAR, "trainer",
               "norm of the aggregated (post-defense) direction"),
    MetricSpec("escape_on", "float32", SCALAR, "trainer",
               "sgd_escape perturbation gate (1 = noise injected)"),
    MetricSpec("attack_level", "float32", SCALAR, "attack",
               "adaptive-attack controller level consumed by this "
               "step's act() (aggression / z / scale / eps / boost)"),
    MetricSpec("true_grad_norm", "float32", SCALAR, "probe",
               "planted-saddle analytic gradient norm (DESIGN.md §14)"),
    MetricSpec("min_eig_proxy", "float32", SCALAR, "probe",
               "Rayleigh min-eigenvalue proxy along planted directions"),
    MetricSpec("escaped", "float32", SCALAR, "probe",
               "analytic escape predicate of the current iterate"),
])

# --------------------------------------------------------------------------
# The tap surface: the bounded per-window summary scan_trial streams out
# of a running scan (tap_every=K).  Every key is a scalar; ``agg`` says
# how the K-step window reduces to it (``mean`` / ``last``), or ``host``
# for keys the host-side collector stamps on (never traced).
# --------------------------------------------------------------------------

TAP: Dict[str, MetricSpec] = _spec_table([
    MetricSpec("step", "int32", SCALAR, "trainer",
               "global step count at the window's end", agg="last"),
    MetricSpec("loss", "float32", SCALAR, "trainer",
               "window-mean per-worker training loss", agg="mean"),
    MetricSpec("honest_loss", "float32", SCALAR, "trainer",
               "window-mean honest training loss", agg="mean"),
    MetricSpec("grad_norm", "float32", SCALAR, "trainer",
               "aggregated-direction norm at the window's last step",
               agg="last"),
    MetricSpec("n_good", "float32", SCALAR, "trainer",
               "live good-set size (popcount) at the window's last step",
               agg="last"),
    MetricSpec("caught_byz", "int32", SCALAR, "trainer",
               "Byzantine workers outside the good set, window end",
               agg="last"),
    MetricSpec("evicted_honest", "int32", SCALAR, "trainer",
               "honest workers outside the good set, window end",
               agg="last"),
    MetricSpec("threshold_B", "float32", SCALAR, "trainer",
               "live inner (T0) eviction threshold, window end",
               window="B", agg="last"),
    MetricSpec("threshold_A", "float32", SCALAR, "trainer",
               "live outer (T1) eviction threshold, window end",
               window="A", agg="last"),
    MetricSpec("min_eig_proxy", "float32", SCALAR, "probe",
               "Rayleigh min-eigenvalue proxy, window end", agg="last"),
    MetricSpec("escape_on", "float32", SCALAR, "trainer",
               "sgd_escape gate at the window's last step", agg="last"),
    MetricSpec("attack_level", "float32", SCALAR, "attack",
               "adaptive-attack controller level, window end", agg="last"),
    MetricSpec("lane", "int32", SCALAR, "trainer",
               "vmap lane index inside the emitting batch group (threaded "
               "through the device payload: vmapped callbacks fire "
               "per-lane with no other lane identity)", agg="last"),
    MetricSpec("step_rate", "float32", SCALAR, "trainer",
               "host-measured steps/s since the lane's previous "
               "heartbeat", agg="host"),
    MetricSpec("t_wall", "float32", SCALAR, "trainer",
               "host wall-clock seconds since the collector attached",
               agg="host"),
])

# tap keys that cross the device->host boundary (everything not host-
# stamped), in a fixed order — the io_callback payload is this tuple
DEVICE_TAP_KEYS = tuple(
    n for n, s in TAP.items() if s.agg != "host")

_SURFACES = {METRIC_SURFACE: METRICS, INFO_SURFACE: INFO, TAP_SURFACE: TAP}


def register_metric(spec: MetricSpec, surface: str = METRIC_SURFACE,
                    overwrite: bool = False) -> MetricSpec:
    """Register a new statistic (e.g. a custom ``so_probe`` output).
    Refuses to silently redefine an existing name."""
    table = _SURFACES[surface]
    if spec.name in table and not overwrite:
        raise SchemaError(f"metric {spec.name!r} already registered on the "
                          f"{surface} surface as {table[spec.name]}; pass "
                          "overwrite=True to redefine")
    table[spec.name] = spec
    return spec


# --------------------------------------------------------------------------
# Validation (trace-time: shapes/dtypes of tracers are static)
# --------------------------------------------------------------------------

_KINDS = {"f": "floating", "i": "integer", "u": "integer", "b": "bool"}


def _kind(dtype) -> str:
    dt = np.dtype(dtype)
    # ml_dtypes extension floats (bfloat16, float8_*) register with
    # numpy as kind "V" (void); classify them by name
    if dt.kind == "V" and "float" in dt.name:
        return "floating"
    return _KINDS.get(dt.kind, dt.kind)


def _check(name: str, value, spec: MetricSpec, m: int, where: str) -> None:
    # NB: don't use getattr(value, ..., np.asarray(value)...) — the
    # fallback would be evaluated eagerly, and np.asarray on a jax
    # tracer raises TracerArrayConversionError
    shape = (tuple(value.shape) if hasattr(value, "shape")
             else tuple(np.shape(value)))
    dtype = (value.dtype if hasattr(value, "dtype")
             else np.asarray(value).dtype)
    if spec.shape_class in (SCALAR, PER_WINDOW):
        ok = shape == ()
        want = "()"
    elif spec.shape_class == PER_WORKER:
        ok = shape == (m,)
        want = f"({m},)"
    else:                                           # PER_BUCKET
        ok = len(shape) == 1 and shape[0] >= 1 and m % shape[0] == 0
        want = f"(m/s,) with m={m}"
    if not ok:
        raise SchemaError(
            f"{where}: {name!r} has shape {shape}, but its schema class "
            f"is {spec.shape_class!r} (expects {want})")
    if _kind(dtype) != _kind(spec.dtype):
        raise SchemaError(
            f"{where}: {name!r} has dtype {np.dtype(dtype).name} "
            f"({_kind(dtype)}), but its schema dtype is {spec.dtype} "
            f"({_kind(spec.dtype)})")


def _validate(d: Dict, m: int, table: Dict[str, MetricSpec], where: str
              ) -> None:
    for name, value in d.items():
        spec = table.get(name)
        if spec is None:
            kind = ("info" if table is INFO
                    else "tap" if table is TAP else "metric")
            raise SchemaError(
                f"{where}: {name!r} is not a registered "
                f"{kind} name — add it "
                "to repro.obs.schema (register_metric) so traces stay "
                f"comparable across campaigns; registered: "
                f"{sorted(table)}")
        _check(name, value, spec, m, where)


def validate_metrics(metrics: Dict, m: int, where: str = "train_step"
                     ) -> Dict:
    """Validate a trainer step-metric dict against the schema; returns
    the dict unchanged (chainable).  Call at trace time."""
    _validate(metrics, m, METRICS, where)
    return metrics


def validate_info(info: Dict, m: int, where: str = "defense") -> Dict:
    """Validate a ``Defense.aggregate`` info dict against the schema;
    returns the dict unchanged (chainable)."""
    _validate(info, m, INFO, where)
    return info


def validate_tap(payload: Dict, where: str = "tap") -> Dict:
    """Validate a tap payload (the per-window summary ``scan_trial``
    streams through ``io_callback``) against the tap surface; returns
    the dict unchanged.  Tap keys are all scalars, so ``m`` is moot."""
    _validate(payload, 0, TAP, where)
    return payload


def spec_of(name: str, surface: str = METRIC_SURFACE) -> MetricSpec:
    table = _SURFACES[surface]
    if name not in table:
        raise SchemaError(f"unknown {surface} name {name!r}")
    return table[name]
