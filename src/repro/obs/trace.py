"""Trace artifacts: compressed ``.npz`` sidecars for per-step traces.

A traced campaign cell produces ``steps`` floats per scalar metric and
``steps * m`` per per-worker metric.  Inlining that into the campaign
JSONL (the pre-obs ``store_traces`` path) bloated ``results.jsonl`` by
orders of magnitude and forced every reader through ``json.loads`` of
megabyte lines.  Sidecars fix both: one ``np.savez_compressed`` archive
per cell, keyed by the content-addressed scenario hash, living next to
the JSONL under

    experiments/campaigns/<name>/traces/<scenario_id>.npz

The JSONL record stays small — it carries ``trace_file`` (the relative
sidecar path) and ``trace_fields`` (the stored keys) so a resumed or
copied campaign can locate its artifacts without globbing.  Readers go
through :func:`CampaignStore.load_traces`, which falls back to the
legacy inlined ``result["traces"]`` dict for JSONLs written before this
layer existed.

Arrays round-trip exactly: ``savez`` preserves dtype and shape, so the
obs-smoke integrity check (event log re-derived from the sidecar must
bit-match the log derived from the in-memory traces) is meaningful."""

from __future__ import annotations

import os
from typing import Dict, Optional

import numpy as np

TRACE_SUBDIR = "traces"


def trace_relpath(sid: str) -> str:
    """Sidecar path relative to the campaign directory."""
    return os.path.join(TRACE_SUBDIR, f"{sid}.npz")


def trace_path(campaign_dir: str, sid: str) -> str:
    return os.path.join(campaign_dir, trace_relpath(sid))


def save_traces(campaign_dir: str, sid: str, traces: Dict) -> str:
    """Persist a cell's dense trace dict as a compressed sidecar.

    Returns the path *relative to the campaign dir* (what the JSONL
    record stores).  Written atomically (tmp + rename) so a killed run
    never leaves a torn archive for resume to trip on."""
    path = trace_path(campaign_dir, sid)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    arrays = {k: np.asarray(v) for k, v in traces.items()}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez_compressed(f, **arrays)
    os.replace(tmp, path)
    return trace_relpath(sid)


def load_trace_file(path: str) -> Dict[str, np.ndarray]:
    """Load a sidecar back into a plain dict of numpy arrays."""
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def load_cell_traces(campaign_dir: str, record: Dict
                     ) -> Optional[Dict[str, np.ndarray]]:
    """Resolve a JSONL record's traces: sidecar if it names one, else the
    legacy inlined dict, else None.

    Legacy inlined traces were jsonified (nested lists), so they come
    back as float64/int64 arrays — exact for the f32 values that were
    widened on write, but dtype-widened; sidecars preserve dtype."""
    result = record.get("result", {})
    rel = result.get("trace_file")
    if rel:
        path = os.path.join(campaign_dir, rel)
        if not os.path.exists(path):
            raise FileNotFoundError(
                f"record {record.get('id')!r} names trace sidecar {rel!r} "
                f"but {path} does not exist (moved campaign dir without "
                "its traces/ subdirectory?)")
        return load_trace_file(path)
    inline = result.get("traces")
    if inline is not None:
        return {k: np.asarray(v) for k, v in inline.items()}
    return None
