"""Wall-clock phase attribution — the profiling hooks of the flight
recorder (DESIGN.md §15).

Answers "where did the benchmark's seconds go": compile (trace + XLA)
vs execute vs host-side work, with ``launch.hlo_analysis`` cost
attribution on the compiled program.  Two entry points:

* :class:`PhaseTimer` — a context-manager accumulator for coarse phases
  (``with pt.phase("build"): ...``); nested phases are not double
  counted because only the innermost active phase accrues time.
* :func:`profile_compiled` — AOT-compiles one jitted callable
  (``jax.jit(f).lower(*args).compile()``) so compile time is measured
  apart from the first execution (jit's usual dispatch hides it there),
  then times ``repeats`` executions, and attributes program cost via
  ``hlo_analysis.analyze_hlo`` (loop-aware FLOPs / HBM bytes — XLA's
  own ``cost_analysis`` counts while-loop bodies once).

``benchmarks/trace_overhead.py`` uses both to prove full-schema trace
capture stays within 5% of ``trace_zeta=False``
(``BENCH_trace_overhead.json``)."""

from __future__ import annotations

import contextlib
import time
from typing import Callable, Dict, Optional

import jax


class PhaseTimer:
    """Accumulate wall-clock into named phases.

    Only the innermost active phase accrues: entering ``execute`` inside
    ``total`` pauses ``total``'s accumulation, so phase seconds are
    disjoint and sum to measured wall-clock."""

    def __init__(self):
        self.seconds: Dict[str, float] = {}
        # full (name, enter, exit, depth) spans in perf_counter seconds —
        # unlike ``seconds`` these are NOT innermost-only: a span covers
        # its children, which is exactly the nesting a Chrome-trace /
        # Perfetto flame view expects (repro.obs.perfetto)
        self.spans: list = []
        self._stack: list = []          # [(name, started_at), ...]

    @contextlib.contextmanager
    def phase(self, name: str):
        enter = now = time.perf_counter()
        if self._stack:                 # pause the enclosing phase
            outer, t0 = self._stack[-1]
            self.seconds[outer] = self.seconds.get(outer, 0.0) + now - t0
        depth = len(self._stack)
        self._stack.append((name, now))
        try:
            yield self
        finally:
            now = time.perf_counter()
            _, t0 = self._stack.pop()
            self.seconds[name] = self.seconds.get(name, 0.0) + now - t0
            self.spans.append((name, enter, now, depth))
            if self._stack:             # resume the enclosing phase
                outer, _ = self._stack[-1]
                self._stack[-1] = (outer, now)

    def summary(self) -> Dict[str, float]:
        total = sum(self.seconds.values())
        out = {f"{k}_s": round(v, 6) for k, v in sorted(self.seconds.items())}
        out["total_s"] = round(total, 6)
        for k, v in sorted(self.seconds.items()):
            out[f"{k}_frac"] = round(v / total, 4) if total else 0.0
        return out


def profile_compiled(fn: Callable, *args, repeats: int = 3,
                     analyze: bool = True) -> Dict:
    """AOT compile + timed executions of one jittable callable.

    Returns ``{"lower_s", "compile_s", "execute_s" (best of repeats),
    "execute_mean_s", "hlo": {flops, hbm_bytes, ...}}``.  ``args`` are
    the concrete example arguments; results are block-until-ready'd so
    execute time is real device time, not dispatch time."""
    t0 = time.perf_counter()
    lowered = jax.jit(fn).lower(*args)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()

    times = []
    out = None
    for _ in range(max(1, repeats)):
        ta = time.perf_counter()
        out = compiled(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - ta)

    rec: Dict = {
        "lower_s": round(t1 - t0, 6),
        "compile_s": round(t2 - t1, 6),
        "execute_s": round(min(times), 6),
        "execute_mean_s": round(sum(times) / len(times), 6),
        "repeats": len(times),
    }
    if analyze:
        from repro.launch.hlo_analysis import analyze_hlo
        try:
            rec["hlo"] = analyze_hlo(compiled.as_text())
        except Exception as e:                            # noqa: BLE001
            rec["hlo"] = {"error": repr(e)}
    rec["_out"] = out       # callers may want the result; strip for json
    return rec


def strip_private(rec: Dict) -> Dict:
    """Drop non-serializable keys (``_out``) before json-dumping."""
    return {k: v for k, v in rec.items() if not k.startswith("_")}
