"""Health-rule engine over live heartbeat streams (DESIGN.md §17).

Each rule is a pure function of one cell's ordered heartbeat stream
(the JSONL lines ``repro.obs.live`` persists) returning zero or more
:class:`Alert` records.  Rules only ever *read* the typed tap surface
(``repro.obs.schema.TAP``); a key a program does not emit simply
disarms the rules that need it, so the same catalog runs over every
lane (saddle lanes arm ``stalled_escape``, mean-defense lanes never arm
``eviction_storm``).

The catalog (tunable per :class:`AlertConfig`):

  ``nan_guard``           critical — a non-finite value crossed the tap
                          surface (loss, thresholds, grad/eig proxies):
                          the aggregate is poisoned, nothing downstream
                          of this step is trustworthy.
  ``eviction_storm``      the live good set shrank by ``storm_k`` or
                          more workers below its running max — either
                          the defense is catching a coordinated attack
                          or it is mass-evicting honest workers; both
                          deserve eyes.  Re-arms after a periodic-reset
                          restore.
  ``threshold_runaway``   a live guard threshold exceeded
                          ``runaway_factor`` x its early-stream median —
                          the signature of a threshold-tracking
                          adversary ratcheting the guard open.
  ``stalled_escape``      the saddle-escape perturbation has been
                          continuously active for ``stall_beats``
                          heartbeats while the min-eigenvalue proxy
                          stays negative: noise is being injected but
                          the iterate is not leaving the saddle.
  ``step_rate_collapse``  host-measured steps/s fell below
                          ``collapse_frac`` x the cell's median rate —
                          the run is still alive but something
                          (swapping, contention, a straggler host) ate
                          its throughput.

``extract_alerts`` runs the whole catalog; ``repro.obs.live alerts``
(the CLI) and ``repro.obs.report`` (the forensics report) both feed
from it.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

CRITICAL = "critical"
WARNING = "warning"

# float tap keys nan_guard watches (a non-finite int cannot happen)
_FINITE_KEYS = ("loss", "honest_loss", "grad_norm", "threshold_B",
                "threshold_A", "min_eig_proxy", "attack_level")


@dataclasses.dataclass(frozen=True)
class Alert:
    """One structured health alert, anchored to a cell + step."""
    rule: str
    severity: str
    cell: str
    step: int
    message: str

    def format(self) -> str:
        return (f"ALERT [{self.severity}] {self.rule} cell={self.cell} "
                f"step={self.step}: {self.message}")


@dataclasses.dataclass(frozen=True)
class AlertConfig:
    """Rule thresholds.  Defaults are calibrated on the smoke campaign:
    loose enough that a clean (attack-free) safeguard lane is silent,
    tight enough that the variance attack's eviction burst fires."""
    storm_k: int = 2                # good-set drop that counts as a storm
    runaway_factor: float = 50.0    # threshold blow-up vs early median
    runaway_warmup: int = 3         # beats used for the early median
    stall_beats: int = 3            # consecutive active-escape heartbeats
    collapse_frac: float = 0.25     # step-rate floor vs running median
    rate_warmup: int = 3            # beats before rate judgments


def _finite(x) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x)


# --------------------------------------------------------------------------
# Rules — each: (beats, cell, cfg) -> [Alert]
# --------------------------------------------------------------------------

def rule_nan_guard(beats: List[Dict], cell: str, cfg: AlertConfig
                   ) -> List[Alert]:
    for b in beats:
        bad = [k for k in _FINITE_KEYS
               if k in b and not _finite(b[k])]
        if bad:
            return [Alert(
                "nan_guard", CRITICAL, cell, int(b.get("step", -1)),
                f"non-finite tap value(s) {bad} — the aggregate is "
                "poisoned; every later step descends garbage")]
    return []


def _evicted_count(b: Dict, n_good_max: Optional[float]
                   ) -> Optional[float]:
    """Workers currently outside the good set.  Prefer the tapped
    eviction counters (they see evictions that happened before the
    first heartbeat); fall back to the good-set drop below its running
    max when a program taps only ``n_good``."""
    caught, ev = b.get("caught_byz"), b.get("evicted_honest")
    if _finite(caught) and _finite(ev):
        return caught + ev
    n = b.get("n_good")
    if _finite(n) and n_good_max is not None:
        return max(n_good_max - n, 0.0)
    return None


def rule_eviction_storm(beats: List[Dict], cell: str, cfg: AlertConfig
                        ) -> List[Alert]:
    """Fire when the evicted-worker count rises ``storm_k`` or more
    above its low watermark; a periodic-reset restore lowers the
    watermark and re-arms the rule (each storm alerts once)."""
    out: List[Alert] = []
    low: Optional[float] = None
    n_good_max: Optional[float] = None
    for b in beats:
        n = b.get("n_good")
        if _finite(n):
            n_good_max = n if n_good_max is None else max(n_good_max, n)
        ev = _evicted_count(b, n_good_max)
        if ev is None:
            continue
        # the watermark starts at 0, not the first beat's count: every
        # defense starts with the full good set, so evictions that land
        # before the first heartbeat still count toward the storm
        low = 0.0 if low is None else min(low, ev)
        if ev - low >= cfg.storm_k:
            out.append(Alert(
                "eviction_storm", WARNING, cell, int(b.get("step", -1)),
                f"{ev - low:.0f} workers evicted since the last quiet "
                f"point (caught_byz={b.get('caught_byz', '?')}, "
                f"evicted_honest={b.get('evicted_honest', '?')}, "
                f"n_good={b.get('n_good', '?')}) — mass eviction in "
                "flight"))
            low = ev                        # one alert per storm
    return out


def rule_threshold_runaway(beats: List[Dict], cell: str, cfg: AlertConfig
                           ) -> List[Alert]:
    out: List[Alert] = []
    for key in ("threshold_B", "threshold_A"):
        series = [b for b in beats if _finite(b.get(key))
                  and b[key] > 0]
        if len(series) <= cfg.runaway_warmup:
            continue
        early = sorted(b[key] for b in series[:cfg.runaway_warmup])
        base = early[len(early) // 2]
        if base <= 0:
            continue
        for b in series[cfg.runaway_warmup:]:
            if b[key] >= cfg.runaway_factor * base:
                out.append(Alert(
                    "threshold_runaway", WARNING, cell,
                    int(b.get("step", -1)),
                    f"{key}={b[key]:.4g} is {b[key] / base:.0f}x its "
                    f"early-stream median {base:.4g} — a threshold-"
                    "tracking adversary may be ratcheting the guard "
                    "open"))
                break                        # one alert per guard
    return out


def rule_stalled_escape(beats: List[Dict], cell: str, cfg: AlertConfig
                        ) -> List[Alert]:
    streak = 0
    for b in beats:
        on = b.get("escape_on")
        eig = b.get("min_eig_proxy")
        if not (_finite(on) and _finite(eig)):
            streak = 0
            continue
        if on >= 0.5 and eig < 0:
            streak += 1
            if streak >= cfg.stall_beats:
                return [Alert(
                    "stalled_escape", WARNING, cell,
                    int(b.get("step", -1)),
                    f"escape noise active for {streak} consecutive "
                    f"heartbeats with min_eig_proxy={eig:.4g} still "
                    "negative — the iterate is pinned at the saddle "
                    "(is escape_nu large enough for this gap?)")]
        else:
            streak = 0
    return []


def rule_step_rate_collapse(beats: List[Dict], cell: str, cfg: AlertConfig
                            ) -> List[Alert]:
    rates: List[float] = []
    armed = True
    out: List[Alert] = []
    for b in beats:
        r = b.get("step_rate")
        if not _finite(r) or r <= 0:
            continue
        if len(rates) >= cfg.rate_warmup:
            med = sorted(rates)[len(rates) // 2]
            if armed and r < cfg.collapse_frac * med:
                out.append(Alert(
                    "step_rate_collapse", WARNING, cell,
                    int(b.get("step", -1)),
                    f"step rate {r:.2f}/s is below "
                    f"{cfg.collapse_frac:.0%} of the cell median "
                    f"{med:.2f}/s — throughput collapsed"))
                armed = False
            elif not armed and r >= cfg.collapse_frac * med:
                armed = True
        rates.append(r)
    return out


RULES = (rule_nan_guard, rule_eviction_storm, rule_threshold_runaway,
         rule_stalled_escape, rule_step_rate_collapse)


def extract_alerts(beats: List[Dict], cell: str = "?",
                   cfg: Optional[AlertConfig] = None) -> List[Alert]:
    """Run the full rule catalog over one cell's ordered heartbeat
    stream."""
    cfg = cfg or AlertConfig()
    out: List[Alert] = []
    for rule in RULES:
        out.extend(rule(beats, cell, cfg))
    out.sort(key=lambda a: (a.step, a.rule))
    return out


def alerts_for_campaign(root, campaign: str,
                        cfg: Optional[AlertConfig] = None
                        ) -> Dict[str, List[Alert]]:
    """Alerts per cell from a campaign store's heartbeat directory
    (empty dict when the campaign was never run with tapping)."""
    from repro.obs import live as live_lib
    streams = live_lib.load_heartbeats(live_lib.live_dir(root, campaign))
    return {cell: extract_alerts(beats, cell=cell, cfg=cfg)
            for cell, beats in streams.items()}
