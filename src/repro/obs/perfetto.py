"""Chrome-trace / Perfetto exporter for the perf side of the flight
recorder (DESIGN.md §17).

Everything the repo already measures — :class:`repro.obs.profile.
PhaseTimer` spans, the AOT lower/compile/execute split of
``profile_compiled``, and the loop-aware collective-bytes attribution of
``launch.hlo_analysis`` — rendered as one Chrome trace-event JSON file
(the format both ``chrome://tracing`` and https://ui.perfetto.dev
open).  Event vocabulary used:

  ``ph="X"``  complete span (``ts``/``dur`` in microseconds)
  ``ph="C"``  counter sample (collective bytes per program)
  ``ph="M"``  metadata (process/thread names — one process per campaign
              program, threads = phases)

The CLI AOT-profiles every batch-key program of a campaign (the same
program enumeration the campaign engine executes) and writes one trace:

    PYTHONPATH=src python -m repro.obs.perfetto --campaign smoke \\
        --quick --out /tmp/smoke_trace.json

``validate_chrome_trace`` is the schema gate tests (and the benchmark
regression harness) run over any exported trace — Perfetto itself is
not in CI, so the contract lives here.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

US = 1e6                                 # seconds -> microseconds

_PHASES = ("X", "C", "M", "B", "E", "i")


def span_event(name: str, t0_s: float, t1_s: float, *, pid: int = 0,
               tid: int = 0, cat: str = "phase",
               args: Optional[Dict] = None) -> Dict:
    """One complete-span ("X") trace event from a [t0, t1] second
    interval."""
    ev = {"name": name, "ph": "X", "cat": cat,
          "ts": round(t0_s * US, 3), "dur": round((t1_s - t0_s) * US, 3),
          "pid": pid, "tid": tid}
    if args:
        ev["args"] = args
    return ev


def counter_event(name: str, t_s: float, values: Dict[str, float], *,
                  pid: int = 0) -> Dict:
    """One counter ("C") sample — Perfetto draws a stacked track per
    series in ``values``."""
    return {"name": name, "ph": "C", "ts": round(t_s * US, 3),
            "pid": pid, "args": {k: float(v) for k, v in values.items()}}


def meta_event(what: str, label: str, *, pid: int = 0,
               tid: Optional[int] = None) -> Dict:
    ev = {"name": what, "ph": "M", "ts": 0.0, "pid": pid,
          "args": {"name": label}}
    if tid is not None:
        ev["tid"] = tid
    return ev


def timer_events(pt, *, pid: int = 0, tid: int = 0,
                 t0: Optional[float] = None) -> List[Dict]:
    """PhaseTimer spans -> "X" events on one thread timeline.  ``t0``
    rebases timestamps (default: the earliest span's enter time, so the
    trace starts at ts=0)."""
    if not pt.spans:
        return []
    base = min(s[1] for s in pt.spans) if t0 is None else t0
    return [span_event(name, enter - base, leave - base, pid=pid,
                       tid=tid, args={"depth": depth})
            for name, enter, leave, depth in sorted(pt.spans,
                                                    key=lambda s: s[1])]


def profile_events(rec: Dict, *, pid: int = 0, t0_s: float = 0.0,
                   label: str = "program") -> List[Dict]:
    """``profile_compiled`` record -> lower/compile/execute spans laid
    end-to-end from ``t0_s``, plus a collective-bytes counter sample
    when the record carries an hlo analysis.  Returns the events and
    leaves the caller to advance its own timeline cursor (use
    :func:`profile_span_s`)."""
    t = t0_s
    out: List[Dict] = []
    for tid, key in enumerate(("lower_s", "compile_s", "execute_s")):
        dur = float(rec.get(key, 0.0))
        out.append(span_event(key[:-2], t, t + dur, pid=pid, tid=tid,
                              cat="aot", args={"label": label}))
        t += dur
    hlo = rec.get("hlo") or {}
    coll = {k: v for k, v in (hlo.get("collective_bytes") or {}).items()
            if v}
    if coll:    # single-device programs have no collectives: no track
        out.append(counter_event("collective_bytes", t0_s, coll,
                                 pid=pid))
        counts = {k: v for k, v
                  in (hlo.get("collective_counts") or {}).items() if v}
        if counts:
            out.append(counter_event("collective_counts", t0_s, counts,
                                     pid=pid))
    return out


def profile_span_s(rec: Dict) -> float:
    """Total seconds the :func:`profile_events` timeline occupies."""
    return sum(float(rec.get(k, 0.0))
               for k in ("lower_s", "compile_s", "execute_s"))


def chrome_trace(events: List[Dict]) -> Dict:
    """Wrap events in the Chrome trace-event container."""
    return {"traceEvents": list(events), "displayTimeUnit": "ms"}


def validate_chrome_trace(obj: Dict) -> List[Dict]:
    """Assert ``obj`` is a well-formed Chrome trace-event JSON object;
    returns the event list.  Raises :class:`ValueError` naming the
    first offending event — this is the schema contract tests run,
    since Perfetto itself is not importable in CI."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("chrome trace must be an object with a "
                         "'traceEvents' array")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            raise ValueError(f"{where}: not an object")
        for req in ("name", "ph", "pid"):
            if req not in ev:
                raise ValueError(f"{where}: missing {req!r}")
        if ev["ph"] not in _PHASES:
            raise ValueError(f"{where}: unknown phase {ev['ph']!r}")
        if ev["ph"] != "M" and not isinstance(ev.get("ts"), (int, float)):
            raise ValueError(f"{where}: 'ts' must be a number")
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"{where}: X event needs dur >= 0")
        if ev["ph"] == "C" and not isinstance(ev.get("args"), dict):
            raise ValueError(f"{where}: C event needs an args dict")
    return events


# --------------------------------------------------------------------------
# Campaign export
# --------------------------------------------------------------------------

def export_campaign(campaign: str, *, steps: int = 40, seeds: int = 1,
                    repeats: int = 2, limit: Optional[int] = None,
                    timer=None) -> Dict:
    """AOT-profile every batch-key program of ``campaign`` and render
    one Chrome trace: per program a process with lower/compile/execute
    spans and collective counter tracks; optionally a ``timer``
    (PhaseTimer) process for the harness's own phases."""
    import jax

    from repro.campaign import engine
    from repro.campaign.run import CAMPAIGNS
    from repro.obs import profile as prof

    scenarios = CAMPAIGNS[campaign](seeds, steps)
    groups = engine.group_scenarios(scenarios)
    if limit is not None:
        groups = groups[:limit]
    events: List[Dict] = []
    cursor = 0.0
    for pid, group in enumerate(groups, start=1):
        rep = group[0]
        label = (f"{rep.attack}/{rep.defense}/{rep.task}"
                 f"/lanes={len(group)}")
        trial = engine.make_trial_fn(rep)
        knobs = engine.stack_knobs(group)
        rec = prof.profile_compiled(jax.vmap(trial), knobs,
                                    repeats=repeats)
        events.append(meta_event("process_name", label, pid=pid))
        for tid, tname in enumerate(("lower", "compile", "execute")):
            events.append(meta_event("thread_name", tname, pid=pid,
                                     tid=tid))
        events.extend(profile_events(rec, pid=pid, t0_s=cursor,
                                     label=label))
        cursor += profile_span_s(rec)
    if timer is not None and timer.spans:
        events.append(meta_event("process_name", "harness", pid=0))
        events.extend(timer_events(timer, pid=0, t0=None))
    return chrome_trace(events)


def main(argv=None) -> int:
    import argparse

    from repro.campaign.run import CAMPAIGNS
    from repro.obs.profile import PhaseTimer

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.perfetto",
        description="export campaign AOT profiles as a Chrome/Perfetto "
                    "trace")
    ap.add_argument("--campaign", default="smoke",
                    choices=sorted(CAMPAIGNS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--limit", type=int, default=None,
                    help="profile only the first N programs")
    ap.add_argument("--out", default="/tmp/campaign_trace.json")
    args = ap.parse_args(argv)

    steps = args.steps if args.steps is not None else (40 if args.quick
                                                       else 150)
    pt = PhaseTimer()
    with pt.phase("export"):
        trace = export_campaign(args.campaign, steps=steps,
                                seeds=args.seeds, repeats=args.repeats,
                                limit=args.limit)
    # the harness's own span lands after the phase exits (spans record
    # on exit), as its own process timeline
    trace["traceEvents"].append(meta_event("process_name", "harness",
                                           pid=0))
    trace["traceEvents"].extend(timer_events(pt, pid=0))
    validate_chrome_trace(trace)
    with open(args.out, "w") as f:
        json.dump(trace, f)
    n = len(trace["traceEvents"])
    print(f"perfetto,{args.campaign},events={n},out={args.out}")
    print("open in https://ui.perfetto.dev or chrome://tracing")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
