"""Flight recorder: typed schemas, trace artifacts, decision events,
forensics reports, and phase profiling (DESIGN.md §15).

Layers:

* :mod:`repro.obs.schema`  — typed metric/info registry + trace-time
  validation (:func:`validate_metrics`, :func:`validate_info`);
* :mod:`repro.obs.trace`   — compressed ``.npz`` trace sidecars keyed by
  scenario hash, with back-compat reads of JSONL-inlined traces;
* :mod:`repro.obs.events`  — pure-numpy dense-trace -> event-log
  extraction (evictions, restorations, threshold crossings, escape
  firings, attack phase changes) plus replay/summary primitives;
* :mod:`repro.obs.report`  — ``python -m repro.obs.report`` forensics
  CLI ("why was worker k evicted at step t") + markdown campaign
  reports;
* :mod:`repro.obs.profile` — wall-clock phase attribution (compile vs
  execute vs defense) with ``launch.hlo_analysis`` cost attribution.
"""

from repro.obs.schema import (MetricSpec, SchemaError, INFO, METRICS,
                              register_metric, spec_of,
                              validate_info, validate_metrics)
from repro.obs.trace import (load_cell_traces, load_trace_file,
                             save_traces, trace_path, trace_relpath)
from repro.obs.events import (Event, caught_curve, eviction_record,
                              events_from_json, events_to_json,
                              extract_events, replay_good, summarize)

__all__ = [
    "MetricSpec", "SchemaError", "INFO", "METRICS", "register_metric",
    "spec_of", "validate_info", "validate_metrics",
    "load_cell_traces", "load_trace_file", "save_traces", "trace_path",
    "trace_relpath",
    "Event", "caught_curve", "eviction_record", "events_from_json",
    "events_to_json", "extract_events", "replay_good", "summarize",
]
