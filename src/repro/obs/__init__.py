"""Flight recorder: typed schemas, trace artifacts, decision events,
forensics reports, and phase profiling (DESIGN.md §15).

Layers:

* :mod:`repro.obs.schema`  — typed metric/info registry + trace-time
  validation (:func:`validate_metrics`, :func:`validate_info`);
* :mod:`repro.obs.trace`   — compressed ``.npz`` trace sidecars keyed by
  scenario hash, with back-compat reads of JSONL-inlined traces;
* :mod:`repro.obs.events`  — pure-numpy dense-trace -> event-log
  extraction (evictions, restorations, threshold crossings, escape
  firings, attack phase changes) plus replay/summary primitives;
* :mod:`repro.obs.report`  — ``python -m repro.obs.report`` forensics
  CLI ("why was worker k evicted at step t") + markdown campaign
  reports;
* :mod:`repro.obs.profile` — wall-clock phase attribution (compile vs
  execute vs defense) with ``launch.hlo_analysis`` cost attribution;
* :mod:`repro.obs.live`    — layer-4 live telemetry: the host-side
  :class:`LiveCollector` behind ``scan_trial(tap_every=K)``'s
  ``io_callback`` taps, heartbeat JSONL persistence, and the
  ``python -m repro.obs.live`` tail/alerts CLI (DESIGN.md §17);
* :mod:`repro.obs.alerts`  — pure rule engine over heartbeat streams
  (NaN guard, eviction storms, threshold runaway, stalled saddle
  escape, step-rate collapse);
* :mod:`repro.obs.perfetto` — Chrome-trace/Perfetto exporter for
  PhaseTimer spans + AOT profiles + collective counters.
"""

from repro.obs.schema import (MetricSpec, SchemaError, INFO, METRICS,
                              TAP, register_metric, spec_of,
                              validate_info, validate_metrics,
                              validate_tap)
from repro.obs.trace import (load_cell_traces, load_trace_file,
                             save_traces, trace_path, trace_relpath)
from repro.obs.events import (Event, caught_curve, eviction_record,
                              events_from_json, events_to_json,
                              extract_events, replay_good, summarize)
# live/alerts resolve lazily (PEP 562): `python -m repro.obs.live`
# executes the module AND imports this package — an eager import here
# would double-load it (runpy's sys.modules warning)
_LAZY = {name: "repro.obs.live"
         for name in ("LiveCollector", "format_beat", "latest_beats",
                      "live_dir", "load_heartbeats")}
_LAZY.update({name: "repro.obs.alerts"
              for name in ("Alert", "AlertConfig", "alerts_for_campaign",
                           "extract_alerts")})


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "MetricSpec", "SchemaError", "INFO", "METRICS", "TAP",
    "register_metric", "spec_of", "validate_info", "validate_metrics",
    "validate_tap",
    "load_cell_traces", "load_trace_file", "save_traces", "trace_path",
    "trace_relpath",
    "Event", "caught_curve", "eviction_record", "events_from_json",
    "events_to_json", "extract_events", "replay_good", "summarize",
    "LiveCollector", "format_beat", "latest_beats", "live_dir",
    "load_heartbeats",
    "Alert", "AlertConfig", "alerts_for_campaign", "extract_alerts",
]
