"""Live telemetry collector — layer 4 of the flight recorder
(DESIGN.md §17).

``scan_trial(tap_every=K)`` streams one bounded scalar payload per
K-step window out of the running scan through
``jax.experimental.io_callback`` (the tap surface of
``repro.obs.schema``).  This module is the host side of that pipe:

  * :class:`LiveCollector` — the callback target.  Each payload is
    stamped with host wall-clock (``t_wall``) and the lane's measured
    ``step_rate``, appended to a bounded in-memory ring buffer, and —
    when a heartbeat directory is attached — persisted as one JSONL
    line per beat under ``<store>/live/<cell>.jsonl``.  The collector
    is thread-safe (XLA may invoke callbacks off the main thread) and
    never raises into the device program: a failing beat is counted in
    ``.dropped`` and the scan keeps running (telemetry must not be able
    to kill the experiment it watches).
  * ``load_heartbeats`` / ``latest_beats`` — read the per-cell JSONL
    streams back.
  * the CLI — ``python -m repro.obs.live tail`` renders a terminal
    dashboard of the latest beat per cell (``--once`` for CI);
    ``python -m repro.obs.live alerts`` runs the ``repro.obs.alerts``
    rule engine over the stored streams and turns expectations
    (``--expect-clean``, ``--expect``) into exit codes for the
    ``live-smoke`` CI gate.

Under the campaign engine's vmap the callback fires once per lane per
window with unbatched scalars; the lane's identity rides inside the
payload (``lane``, threaded via ``tap_meta``) and ``lane_ids`` maps it
back to a cell name for the heartbeat file.
"""

from __future__ import annotations

import collections
import json
import sys
import threading
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.obs import schema as obs_schema

LIVE_DIR = "live"


def _scalar(name: str, v):
    """A json-serializable python scalar from a callback value,
    normalized to the tap surface's canonical dtype kind (the Trainer
    host path floats everything; device payloads arrive typed)."""
    a = np.asarray(v)
    spec = obs_schema.TAP.get(name)
    if spec is not None:
        kind = np.dtype(spec.dtype).kind
    else:
        kind = a.dtype.kind
    if kind in "ui":
        return int(a)
    if kind == "b":
        return bool(a)
    return float(a)


class LiveCollector:
    """Host-side ring buffer + heartbeat writer for scan taps.

    ``lane_ids`` maps the payload's ``lane`` index to a cell name (the
    campaign engine passes the group's scenario ids); without it, beats
    file under ``name`` (the interactive-``Trainer`` case, one lane).
    ``maxlen`` bounds the in-memory ring; heartbeat files are append-
    only and unbounded (one line per K steps — bounded by trial
    length).  Use as a context manager to flush file handles."""

    def __init__(self, *, name: str = "run",
                 lane_ids: Optional[Sequence[str]] = None,
                 heartbeat_dir=None, maxlen: int = 4096,
                 echo=None, clock=time.monotonic):
        self.name = name
        self.lane_ids = list(lane_ids) if lane_ids is not None else None
        self.dir = Path(heartbeat_dir) if heartbeat_dir else None
        self.ring: "collections.deque" = collections.deque(maxlen=maxlen)
        self.dropped = 0
        self.echo = echo                    # callable(line) for live print
        self._clock = clock
        self._t0 = clock()
        self._prev: Dict[str, tuple] = {}   # cell -> (step, t_wall)
        self._files: Dict[str, object] = {}
        self._lock = threading.Lock()
        if self.dir is not None:
            self.dir.mkdir(parents=True, exist_ok=True)

    # -- the io_callback target ------------------------------------------
    def tap(self, payload: Dict) -> None:
        """One heartbeat.  Never raises (a telemetry bug must not kill
        the scan it observes) — failures count in ``.dropped``."""
        try:
            self._tap(payload)
        except Exception:                                # pragma: no cover
            self.dropped += 1

    def _tap(self, device_payload: Dict) -> None:
        beat = {k: _scalar(k, v) for k, v in device_payload.items()}
        lane = beat.get("lane")
        with self._lock:
            if self.lane_ids is not None and lane is not None:
                cell = (self.lane_ids[lane]
                        if 0 <= lane < len(self.lane_ids)
                        else f"lane{lane}")
            else:
                cell = self.name
            beat["cell"] = cell
            t = self._clock() - self._t0
            beat["t_wall"] = round(t, 4)
            prev = self._prev.get(cell)
            if prev is not None and t > prev[1]:
                beat["step_rate"] = round(
                    (beat.get("step", 0) - prev[0]) / (t - prev[1]), 2)
            self._prev[cell] = (beat.get("step", 0), t)
            self.ring.append(beat)
            if self.dir is not None:
                fh = self._files.get(cell)
                if fh is None:
                    fh = open(self.dir / f"{cell}.jsonl", "a")
                    self._files[cell] = fh
                fh.write(json.dumps(beat, sort_keys=True) + "\n")
                fh.flush()
        if self.echo is not None:
            self.echo(format_beat(beat))

    def set_lanes(self, lane_ids: Sequence[str]) -> None:
        """Rebind the lane -> cell mapping (the campaign engine calls
        this before launching each vmapped group; groups run
        sequentially so there is no race with in-flight beats)."""
        with self._lock:
            self.lane_ids = list(lane_ids)
            self._prev.clear()

    # -- lifecycle --------------------------------------------------------
    def close(self) -> None:
        with self._lock:
            for fh in self._files.values():
                fh.close()
            self._files.clear()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- queries ----------------------------------------------------------
    def beats(self, cell: Optional[str] = None) -> List[Dict]:
        with self._lock:
            return [b for b in self.ring
                    if cell is None or b["cell"] == cell]


def format_beat(beat: Dict) -> str:
    """One dashboard line for a heartbeat."""
    parts = [f"step {beat.get('step', '?'):>6}"]
    for key, fmt in (("loss", "{:.4g}"), ("honest_loss", "{:.4g}"),
                     ("n_good", "{:.0f}"), ("caught_byz", "{:d}"),
                     ("threshold_B", "{:.3g}"), ("threshold_A", "{:.3g}"),
                     ("min_eig_proxy", "{:+.3g}"),
                     ("attack_level", "{:.3g}"),
                     ("step_rate", "{:.1f}/s")):
        if key in beat:
            parts.append(f"{key}={fmt.format(beat[key])}")
    return f"[{beat.get('cell', '?')}] " + " ".join(parts)


# --------------------------------------------------------------------------
# Reading heartbeat streams back
# --------------------------------------------------------------------------

def live_dir(root, campaign: str) -> Path:
    """Where a campaign's heartbeat files live: ``<store>/live/``."""
    return Path(root) / campaign / LIVE_DIR


def load_heartbeats(directory) -> Dict[str, List[Dict]]:
    """All per-cell heartbeat streams under ``directory``, keyed by cell
    name, each sorted by step (unordered io_callback may interleave)."""
    out: Dict[str, List[Dict]] = {}
    directory = Path(directory)
    if not directory.is_dir():
        return out
    for path in sorted(directory.glob("*.jsonl")):
        beats = []
        with open(path) as fh:
            for line in fh:
                line = line.strip()
                if line:
                    beats.append(json.loads(line))
        beats.sort(key=lambda b: b.get("step", 0))
        out[path.stem] = beats
    return out


def latest_beats(directory) -> Dict[str, Dict]:
    """The newest heartbeat per cell — the dashboard's data model."""
    return {cell: beats[-1]
            for cell, beats in load_heartbeats(directory).items() if beats}


# --------------------------------------------------------------------------
# CLI: tail dashboard + alert gate
# --------------------------------------------------------------------------

def _render(directory) -> str:
    latest = latest_beats(directory)
    if not latest:
        return f"(no heartbeats under {directory})"
    return "\n".join(format_beat(latest[c]) for c in sorted(latest))


def _cmd_tail(args) -> int:
    directory = live_dir(args.root, args.campaign)
    if args.once:
        print(_render(directory))
        return 0
    try:
        while True:                                      # pragma: no cover
            sys.stdout.write("\x1b[2J\x1b[H")            # clear screen
            print(f"live: {directory}  ({time.strftime('%H:%M:%S')})  "
                  "ctrl-c to quit")
            print(_render(directory))
            time.sleep(args.interval)
    except KeyboardInterrupt:                            # pragma: no cover
        return 0


def _cmd_alerts(args) -> int:
    from repro.obs import alerts as alerts_lib
    directory = live_dir(args.root, args.campaign)
    streams = load_heartbeats(directory)
    if not streams:
        print(f"alerts: no heartbeats under {directory}")
        return 1
    found = {cell: alerts_lib.extract_alerts(beats, cell=cell)
             for cell, beats in streams.items()}
    n = 0
    for cell in sorted(found):
        for a in found[cell]:
            print(a.format())
            n += 1
    print(f"alerts: {n} alert(s) over {len(streams)} cell(s)")
    ok = True
    for substr in args.expect_clean or []:
        cells = [c for c in streams if substr in c]
        if not cells:
            print(f"alerts: --expect-clean {substr!r} matches no cell")
            ok = False
        for c in cells:
            if found[c]:
                print(f"alerts: FAIL — expected clean cell {c} has "
                      f"{len(found[c])} alert(s)")
                ok = False
    for spec in args.expect or []:
        rule, _, substr = spec.partition(":")
        cells = [c for c in streams if substr in c]
        if not cells:
            print(f"alerts: --expect {spec!r} matches no cell")
            ok = False
        elif not any(a.rule == rule for c in cells for a in found[c]):
            print(f"alerts: FAIL — expected a {rule!r} alert on a cell "
                  f"matching {substr!r}, none fired")
            ok = False
    return 0 if ok else 1


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.live",
        description="live heartbeat dashboard + alert gate")
    sub = p.add_subparsers(dest="cmd", required=True)
    t = sub.add_parser("tail", help="terminal dashboard of latest beats")
    t.add_argument("--root", default="experiments/campaigns")
    t.add_argument("--campaign", default="smoke")
    t.add_argument("--once", action="store_true",
                   help="render once and exit (CI)")
    t.add_argument("--interval", type=float, default=2.0)
    a = sub.add_parser("alerts", help="run alert rules over heartbeats")
    a.add_argument("--root", default="experiments/campaigns")
    a.add_argument("--campaign", default="smoke")
    a.add_argument("--expect-clean", action="append", metavar="SUBSTR",
                   help="fail if any cell matching SUBSTR has alerts")
    a.add_argument("--expect", action="append", metavar="RULE:SUBSTR",
                   help="fail unless RULE fires on a cell matching SUBSTR")
    args = p.parse_args(argv)
    return {"tail": _cmd_tail, "alerts": _cmd_alerts}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
