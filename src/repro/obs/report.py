"""Forensics CLI — layer 3 of the flight recorder (DESIGN.md §15).

``python -m repro.obs.report`` reads a campaign directory (the JSONL
store + ``.npz`` trace sidecars) and answers the questions raw traces
can't without scripting:

  # per-campaign markdown report (detection latency, false evictions,
  # caught-fraction curves, event counts per cell)
  python -m repro.obs.report --campaign smoke

  # single-cell forensics: why was worker 4 evicted at step 37?
  python -m repro.obs.report --campaign smoke --cell <scenario-id> \
      --worker 4

  # integrity: assert stored event logs bit-match events re-derived
  # from the raw trace arrays (the obs-smoke invariant)
  python -m repro.obs.report --campaign smoke --check-events

``--cell`` accepts a scenario-id prefix (like git).  The eviction
forensics reconstruct both guards' distance-vs-live-threshold
neighborhoods around the event, so the report shows the approach to the
threshold, the crossing, and the margin — not just the verdict."""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.campaign.store import DEFAULT_ROOT, CampaignStore
from repro.obs import events as ev_lib
from repro.obs import trace as trace_lib


def _cell_label(rec: Dict) -> str:
    s = rec.get("scenario", {})
    bits = [s.get("attack", "?"), s.get("defense", "?"),
            f"seed={s.get('seed', '?')}"]
    for k in ("n_byz", "hetero_alpha", "knob"):
        if s.get(k) not in (None, 0):
            bits.append(f"{k}={s[k]}")
    return "/".join(str(b) for b in bits)


def _cell_events(store: CampaignStore, rec: Dict
                 ) -> Optional[List[ev_lib.Event]]:
    """Stored event log if the record carries one, else re-extracted
    from the cell's traces (sidecar or legacy inline), else None."""
    stored = rec.get("result", {}).get("events")
    if stored is not None:
        return ev_lib.events_from_json(stored)
    traces = trace_lib.load_cell_traces(store.dir, rec)
    if traces is None:
        return None
    return ev_lib.extract_events(traces)


def _resolve_cell(records: Dict[str, Dict], prefix: str) -> Dict:
    hits = [sid for sid in records if sid.startswith(prefix)]
    if not hits:
        raise SystemExit(f"no cell with id prefix {prefix!r}; have "
                         f"{sorted(records)[:8]}...")
    if len(hits) > 1:
        raise SystemExit(f"ambiguous prefix {prefix!r}: {hits}")
    return records[hits[0]]


# --------------------------------------------------------------------------
# Eviction forensics
# --------------------------------------------------------------------------

def eviction_forensics(traces: Dict[str, np.ndarray], worker: int,
                       step: Optional[int] = None, radius: int = 5
                       ) -> str:
    """Markdown narrative: why was ``worker`` evicted (at ``step``, or
    its first eviction)?  Reconstructs each guard's distance vs live
    threshold in ``[step-radius, step+radius]``."""
    events = ev_lib.extract_events(traces)
    e = ev_lib.eviction_record(events, worker, step)
    lines: List[str] = []
    if e is None:
        when = f" at step {step}" if step is not None else ""
        lines.append(f"worker {worker} was never evicted{when}.")
        guards = [g for g in ("B", "A") if f"dist_to_med_{g}" in traces]
        if guards and f"dist_to_med_{guards[0]}" in traces:
            g = guards[0]
            d = np.asarray(traces[f"dist_to_med_{g}"])[:, worker]
            th = np.asarray(traces[f"threshold_{g}"])
            margin = (d / np.maximum(th, 1e-12)).max()
            lines.append(f"closest approach on guard {g}: "
                         f"{margin:.3f} of the live threshold.")
        return "\n".join(lines)

    lines.append(f"### worker {worker} evicted at step {e.step} "
                 f"(guard {e.guard or 'n/a'})")
    lines.append("")
    if np.isfinite(e.value):
        lines.append(f"triggering statistic: dist_to_med = {e.value:.6g} "
                     f">= threshold {e.threshold:.6g} "
                     f"(ratio {e.value / max(e.threshold, 1e-12):.3f})")
        lines.append("")
    lo = max(0, e.step - radius)
    hi = min(next(iter(traces.values())).shape[0], e.step + radius + 1)
    guards = []
    for g in ("B", "A"):
        if f"dist_to_med_{g}" in traces and f"threshold_{g}" in traces:
            guards.append(g)
    if guards:
        hdr = "| step |"
        sep = "|---|"
        for g in guards:
            hdr += f" dist_{g} | thresh_{g} | over_{g} |"
            sep += "---|---|---|"
        lines += [hdr, sep]
        for t in range(lo, hi):
            row = f"| {t}{' *' if t == e.step else ''} |"
            for g in guards:
                d = float(np.asarray(traces[f"dist_to_med_{g}"])[t, worker])
                th = float(np.asarray(traces[f"threshold_{g}"])[t])
                row += f" {d:.5g} | {th:.5g} | {'Y' if d >= th else ''} |"
            lines.append(row)
        lines.append("")
        lines.append(f"(* = eviction step; window [{lo}, {hi - 1}])")
    restore = [x for x in events
               if x.kind == "restoration" and x.worker == worker
               and x.step > e.step]
    if restore:
        lines.append(f"later restored at step(s) "
                     f"{[x.step for x in restore]} by periodic reset.")
    return "\n".join(lines)


# --------------------------------------------------------------------------
# Campaign report
# --------------------------------------------------------------------------

def campaign_report(store: CampaignStore, records: Dict[str, Dict]) -> str:
    lines = [f"# obs report — campaign `{store.name}`", "",
             f"{len(records)} completed cell(s) in `{store.path}`.", ""]
    traced, untraced = [], []
    for sid, rec in sorted(records.items()):
        events = _cell_events(store, rec)
        (traced if events is not None else untraced).append((sid, rec,
                                                            events))
    if untraced:
        lines.append(f"{len(untraced)} cell(s) have no traces/events "
                     "(run the campaign with `--store-traces`); scalar "
                     "results only.")
        lines.append("")
    if traced:
        lines.append("| cell | scenario | events | caught | false ev. | "
                     "first det. | last det. | restores |")
        lines.append("|---|---|---|---|---|---|---|---|")
    for sid, rec, events in traced:
        s = rec.get("scenario", {})
        n_byz = int(s.get("n_byz") or 0)
        m = int(s.get("m") or 0)
        summ = ev_lib.summarize(events, n_byz=n_byz, m=m)
        lines.append(
            f"| `{sid[:10]}` | {_cell_label(rec)} | {summ['n_events']} "
            f"| {summ['n_caught']}/{n_byz} "
            f"| {summ['n_false_evictions']} "
            f"| {summ['detection_latency_first']} "
            f"| {summ['detection_latency_last']} "
            f"| {summ['restorations']} |")
    for sid, rec, events in traced:
        s = rec.get("scenario", {})
        n_byz = int(s.get("n_byz") or 0)
        m = int(s.get("m") or 0)
        summ = ev_lib.summarize(events, n_byz=n_byz, m=m)
        if not summ["caught"]:
            continue
        lines += ["", f"## cell `{sid[:10]}` — {_cell_label(rec)}", ""]
        lines.append("| colluder | evicted at step | guard | dist | "
                     "threshold |")
        lines.append("|---|---|---|---|---|")
        for k, c in summ["caught"].items():
            lines.append(f"| worker {k} | {c['step']} | {c['guard']} "
                         f"| {c['dist']:.6g} | {c['threshold']:.6g} |")
        if n_byz and m:
            steps = None
            traces = trace_lib.load_cell_traces(store.dir, rec)
            if traces is not None and "good" in traces:
                steps = traces["good"].shape[0]
            if steps:
                curve = ev_lib.caught_curve(events, n_byz, m, steps)
                marks = [int(np.argmax(curve >= k)) if (curve >= k).any()
                         else None for k in range(1, n_byz + 1)]
                lines.append("")
                lines.append(f"caught-fraction curve: steps to catch "
                             f"1..{n_byz} colluders = {marks}")
    lines += _alerts_section(store)
    return "\n".join(lines) + "\n"


def _alerts_section(store: CampaignStore) -> List[str]:
    """Live-telemetry alerts (DESIGN.md §17), when the campaign ran
    with ``--tap-every``/``--watch`` and left heartbeat streams under
    ``<store>/live/``.  Absent heartbeats produce no section — stored
    campaigns predating the live layer render unchanged."""
    from pathlib import Path

    from repro.obs import alerts as alerts_lib
    from repro.obs import live as live_lib
    streams = live_lib.load_heartbeats(Path(store.dir) / live_lib.LIVE_DIR)
    if not streams:
        return []
    out = ["", "## live alerts", ""]
    n = 0
    for cell in sorted(streams):
        for a in alerts_lib.extract_alerts(streams[cell], cell=cell):
            out.append(f"- {a.format()}")
            n += 1
    if n == 0:
        out.append(f"none — {len(streams)} heartbeat stream(s) clean")
    else:
        out.append("")
        out.append(f"{n} alert(s) over {len(streams)} stream(s) — "
                   "triage with `python -m repro.obs.live tail` and the "
                   "per-cell forensics above")
    return out


# --------------------------------------------------------------------------
# Integrity check (the obs-smoke invariant)
# --------------------------------------------------------------------------

def check_events(store: CampaignStore, records: Dict[str, Dict]) -> int:
    """Assert stored event logs bit-match events re-derived from the raw
    trace arrays.  Returns the number of cells checked."""
    checked = 0
    for sid, rec in sorted(records.items()):
        stored = rec.get("result", {}).get("events")
        traces = trace_lib.load_cell_traces(store.dir, rec)
        if stored is None or traces is None:
            continue
        fresh = ev_lib.events_to_json(ev_lib.extract_events(traces))
        # json round-trips exactly (f32 -> f64 widening is lossless),
        # so dict equality here IS bit-equality of the event logs —
        # modulo NaN, which json can't carry; compare via repr
        canon = lambda evs: json.dumps(evs, sort_keys=True,
                                       allow_nan=True)
        if canon(fresh) != canon(stored):
            raise SystemExit(
                f"cell {sid}: stored event log does not match events "
                f"recomputed from the raw traces\nstored:   "
                f"{canon(stored)[:400]}\nrecomputed: {canon(fresh)[:400]}")
        # and the event log must replay the trainer's own timeline
        if "good" in traces:
            steps, m = np.asarray(traces["good"]).shape
            evs = ev_lib.events_from_json(stored)
            if not np.array_equal(ev_lib.replay_good(evs, m, steps),
                                  np.asarray(traces["good"]).astype(bool)):
                raise SystemExit(f"cell {sid}: event replay diverges from "
                                 "the traced good timeline")
        checked += 1
    return checked


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="forensics reports over campaign trace artifacts")
    p.add_argument("--campaign", required=True,
                   help="campaign name under the store root")
    p.add_argument("--root", default=DEFAULT_ROOT,
                   help=f"campaign store root (default {DEFAULT_ROOT})")
    p.add_argument("--cell", default=None,
                   help="scenario-id prefix for single-cell forensics")
    p.add_argument("--worker", type=int, default=None,
                   help="worker id: why was this worker evicted?")
    p.add_argument("--step", type=int, default=None,
                   help="restrict --worker forensics to this eviction step")
    p.add_argument("--radius", type=int, default=5,
                   help="neighborhood half-width around the event")
    p.add_argument("--check-events", action="store_true",
                   help="verify stored event logs bit-match re-extraction")
    p.add_argument("--out", default=None,
                   help="write the report here instead of stdout")
    a = p.parse_args(argv)

    store = CampaignStore(a.campaign, root=a.root)
    records = store.load()
    if not records:
        print(f"no completed cells in {store.path}", file=sys.stderr)
        return 1

    if a.check_events:
        n = check_events(store, records)
        print(f"ok: {n} cell(s) with stored events bit-match re-extraction")
        return 0 if n else 1

    if a.worker is not None:
        if a.cell is None:
            raise SystemExit("--worker needs --cell")
        rec = _resolve_cell(records, a.cell)
        traces = trace_lib.load_cell_traces(store.dir, rec)
        if traces is None:
            raise SystemExit(f"cell {rec['id']} has no traces; re-run the "
                             "campaign with --store-traces")
        text = eviction_forensics(traces, a.worker, a.step,
                                  radius=a.radius)
    elif a.cell is not None:
        rec = _resolve_cell(records, a.cell)
        events = _cell_events(store, rec)
        if events is None:
            raise SystemExit(f"cell {rec['id']} has no traces/events")
        s = rec.get("scenario", {})
        summ = ev_lib.summarize(events, n_byz=int(s.get("n_byz") or 0),
                                m=int(s.get("m") or 0))
        text = json.dumps(summ, indent=1, default=str) + "\n"
    else:
        text = campaign_report(store, records)

    if a.out:
        with open(a.out, "w") as f:
            f.write(text)
        print(f"wrote {a.out}")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
