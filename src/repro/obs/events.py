"""Dense traces -> discrete decision events — layer 2 of the flight
recorder (DESIGN.md §15).

The campaign engine's per-step traces are *dense*: ``(steps,)`` scalars
and ``(steps, m)`` per-worker arrays.  Diagnosing a defense decision
("why was worker 3 evicted at step 41?", "when did the attack controller
change phase?") means scanning those arrays for transitions — logic that
was previously re-implemented ad hoc by every benchmark that needed it.
This module is the single extractor: pure numpy (no jax — it runs on
host-side trace pytrees and ``.npz`` sidecars alike), deterministic, and
bit-stable, so an event log persisted at campaign time can be re-derived
from the raw trace arrays and compared for exact equality (the
``obs-smoke`` integrity check).

Event taxonomy (``kind``):

  ``eviction``            ``good[t-1, k] & ~good[t, k]`` — worker ``k``
                          left the good set at step ``t``.  ``guard``
                          names the guard window whose threshold the
                          worker's distance violated (``B``, ``A``,
                          ``BA`` when both, ``""`` when the defense
                          publishes no distances); ``value`` /
                          ``threshold`` are the triggering statistic and
                          the live threshold.
  ``restoration``         ``~good[t-1, k] & good[t, k]`` — periodic
                          reset readmitted worker ``k``.
  ``threshold_crossing``  worker ``k``'s distance-to-median rose from
                          ``< threshold`` to ``>= threshold`` on guard
                          ``B``/``A`` (rising edges only; for a
                          single-guard safeguard the duplicated A-guard
                          surface is suppressed).
  ``escape_fire``         the sgd_escape perturbation gate rose 0 -> 1
                          (``value`` = the aggregate norm that gated
                          it); worker = -1 (global).
  ``attack_phase_change`` the adaptive-attack controller level reversed
                          direction (ramp <-> retreat), the observable
                          phase boundary of the §11 feedback loop;
                          worker = -1, ``value`` = the new level.

Steps index the trace arrays (0-based, one entry per training step);
``good[t]`` is the post-decision mask of step ``t``, so an eviction
event at ``t`` carries the statistics of the very filter call that
evicted.  A worker restored and re-evicted in the same step never
appears as a ``good`` transition — the scalar ``restored`` metric still
counts it (documented limitation; the per-worker reset flag is on the
info surface, not the trace)."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

GLOBAL = -1                       # worker id of global (non-worker) events

# deterministic intra-step ordering of kinds
_KIND_ORDER = ("restoration", "threshold_crossing", "eviction",
               "escape_fire", "attack_phase_change")


@dataclasses.dataclass(frozen=True)
class Event:
    kind: str
    step: int
    worker: int = GLOBAL
    guard: str = ""               # "B" | "A" | "BA" | ""
    value: float = float("nan")   # triggering statistic
    threshold: float = float("nan")  # live threshold (nan when n/a)

    def asdict(self) -> Dict:
        return dataclasses.asdict(self)


def events_to_json(events: List[Event]) -> List[Dict]:
    return [e.asdict() for e in events]


def events_from_json(records: List[Dict]) -> List[Event]:
    return [Event(**r) for r in records]


def _sorted(events: List[Event]) -> List[Event]:
    return sorted(events, key=lambda e: (e.step, _KIND_ORDER.index(e.kind),
                                         e.worker, e.guard))


def _f(x) -> float:
    """Exact float widening (f32 -> f64 is lossless, so json round-trips
    bit-identically)."""
    return float(x)


def _good_timeline(traces: Dict) -> Optional[np.ndarray]:
    good = traces.get("good")
    if good is None:
        return None
    return np.asarray(good).astype(bool)           # (steps, m)


def _guard_surfaces(traces: Dict) -> List[str]:
    """Guard windows with a usable distance/threshold surface.  A
    single-guard safeguard publishes the B statistics twice (A is a
    duplicate) — suppress the mirror so events aren't double-counted."""
    out = []
    for g in ("B", "A"):
        if (f"dist_to_med_{g}" in traces
                and f"threshold_{g}" in traces):
            out.append(g)
    if out == ["B", "A"]:
        same = (np.array_equal(traces["dist_to_med_B"],
                               traces["dist_to_med_A"])
                and np.array_equal(traces["threshold_B"],
                                   traces["threshold_A"]))
        if same:
            out = ["B"]
    return out


def extract_events(traces: Dict) -> List[Event]:
    """Dense host-side trace dict -> ordered discrete event log.

    Tolerant of missing surfaces: a stateless defense has no ``good``
    trace (no eviction events), a non-safeguard filter has no
    distance/threshold surfaces (evictions carry ``guard=""``), a
    non-adaptive attack has no ``attack_level``."""
    traces = {k: np.asarray(v) for k, v in traces.items()}
    events: List[Event] = []
    guards = _guard_surfaces(traces)

    good = _good_timeline(traces)
    if good is not None:
        steps, m = good.shape
        prev = np.ones((m,), bool)                 # everyone starts good
        for t in range(steps):
            evicted = prev & ~good[t]
            restoredv = ~prev & good[t]
            for k in np.flatnonzero(restoredv):
                events.append(Event("restoration", t, int(k)))
            for k in np.flatnonzero(evicted):
                trig, val, th = "", float("nan"), float("nan")
                for g in guards:
                    d = _f(traces[f"dist_to_med_{g}"][t, k])
                    thr = _f(traces[f"threshold_{g}"][t])
                    if d >= thr:
                        trig += g
                        if len(trig) == 1:         # first guard wins value
                            val, th = d, thr
                events.append(Event("eviction", t, int(k), trig, val, th))
            prev = good[t]

    for g in guards:
        dist = traces[f"dist_to_med_{g}"]          # (steps, m)
        th = traces[f"threshold_{g}"][:, None]     # (steps, 1)
        over = dist >= th
        rising = over & ~np.vstack([np.zeros_like(over[:1]), over[:-1]])
        for t, k in zip(*np.nonzero(rising)):
            events.append(Event("threshold_crossing", int(t), int(k), g,
                                _f(dist[t, k]), _f(th[t, 0])))

    esc = traces.get("escape_on")
    if esc is not None:
        on = np.asarray(esc) > 0.5
        rising = on & ~np.concatenate([[False], on[:-1]])
        gnorm = traces.get("grad_norm")
        for t in np.flatnonzero(rising):
            val = _f(gnorm[t]) if gnorm is not None else float("nan")
            events.append(Event("escape_fire", int(t), GLOBAL, "", val))

    level = traces.get("attack_level")
    if level is not None:
        lv = np.asarray(level, np.float64)
        d = np.sign(np.diff(lv))
        prev_dir = 0.0
        for t in range(1, lv.size):
            cur = d[t - 1]
            if cur != 0.0:
                if prev_dir != 0.0 and cur != prev_dir:
                    events.append(Event("attack_phase_change", int(t),
                                        GLOBAL, "", _f(lv[t])))
                prev_dir = cur

    return _sorted(events)


# --------------------------------------------------------------------------
# Replay + summaries (the forensics primitives reports build on)
# --------------------------------------------------------------------------

def replay_good(events: List[Event], m: int, steps: int) -> np.ndarray:
    """Reconstruct the ``(steps, m)`` good-mask timeline from the event
    log alone.  ``replay_good(extract_events(traces), ...)`` must equal
    ``traces["good"]`` exactly — the obs-smoke integrity invariant."""
    good = np.ones((m,), bool)
    out = np.empty((steps, m), bool)
    by_step: Dict[int, List[Event]] = {}
    for e in events:
        if e.kind in ("eviction", "restoration"):
            by_step.setdefault(e.step, []).append(e)
    for t in range(steps):
        for e in by_step.get(t, ()):
            good[e.worker] = e.kind == "restoration"
        out[t] = good
    return out


def caught_curve(events: List[Event], n_byz: int, m: int, steps: int
                 ) -> np.ndarray:
    """Per-step count of evicted Byzantine workers (rows ``< n_byz``),
    replayed from events — must match the trainer's ``caught_byz``
    trace exactly."""
    good = replay_good(events, m, steps)
    return (~good[:, :n_byz]).sum(axis=1).astype(np.int64)


def eviction_record(events: List[Event], worker: int,
                    step: Optional[int] = None) -> Optional[Event]:
    """The eviction event of ``worker`` (at ``step``, or its first)."""
    for e in events:
        if e.kind == "eviction" and e.worker == worker:
            if step is None or e.step == step:
                return e
    return None


def summarize(events: List[Event], *, n_byz: int, m: int) -> Dict:
    """Per-cell forensic summary: first eviction step per worker, the
    caught colluders (byzantine rows are ``< n_byz`` by the engine's
    convention), detection latency, false evictions, restorations."""
    first_evicted: Dict[int, Event] = {}
    restorations = 0
    phase_changes = 0
    escape_fires = 0
    for e in events:
        if e.kind == "eviction" and e.worker not in first_evicted:
            first_evicted[e.worker] = e
        elif e.kind == "restoration":
            restorations += 1
        elif e.kind == "attack_phase_change":
            phase_changes += 1
        elif e.kind == "escape_fire":
            escape_fires += 1
    caught = {k: e for k, e in first_evicted.items() if k < n_byz}
    false_ev = {k: e for k, e in first_evicted.items() if k >= n_byz}
    latencies = [e.step for e in caught.values()]
    return {
        "caught": {k: {"step": e.step, "guard": e.guard,
                       "dist": e.value, "threshold": e.threshold}
                   for k, e in sorted(caught.items())},
        "false_evictions": {k: e.step for k, e in sorted(false_ev.items())},
        "n_caught": len(caught),
        "n_false_evictions": len(false_ev),
        "false_eviction_rate": (len(false_ev) / (m - n_byz)
                                if m > n_byz else 0.0),
        "detection_latency_first": min(latencies) if latencies else None,
        "detection_latency_last": max(latencies) if latencies else None,
        "restorations": restorations,
        "attack_phase_changes": phase_changes,
        "escape_fires": escape_fires,
        "n_events": len(events),
    }
