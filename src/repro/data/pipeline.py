"""Synthetic data pipelines.

Real corpora are unavailable offline, so the pipeline produces seeded
synthetic streams with the structure the training loop expects:

  * ``lm_batches``   — token streams for LM training; tokens are drawn from
    a Zipf-like unigram distribution with a deterministic per-(step,
    worker) seed, so every honest worker sees i.i.d. data from the same
    distribution (the paper's Assumption 2.1 — relaxed by the non-IID
    worker models of ``repro.data.hetero``, DESIGN.md §13);
  * ``stub_batches`` — (embeddings, labels) streams for the stub-frontend
    archs (VLM / audio);
  * ``worker_split`` — reshape a global batch into per-worker slices
    (worker axis first, for the safeguard's vmap);
  * ``flip_labels``  — the paper's label-flipping data attack
    (label l -> n_classes - 1 - l on Byzantine workers' shards).
"""

from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def worker_split(batch, m: int):
    """Split leaves (B, ...) -> (m, B/m, ...)."""
    def one(x):
        B = x.shape[0]
        if B % m:
            raise ValueError(f"batch {B} not divisible by m={m}")
        return x.reshape((m, B // m) + x.shape[1:])
    return jax.tree.map(one, batch)


def flip_labels(labels, n_classes: int):
    """Paper Section 5: label l becomes n_classes - 1 - l."""
    return n_classes - 1 - labels


def _zipf_logits(vocab: int, alpha: float = 1.1):
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return jnp.asarray(np.log(p / p.sum()), jnp.float32)


def lm_batches(vocab: int, batch: int, seq_len: int, *, seed: int = 0,
               m: Optional[int] = None, flip_mask=None,
               alpha: float = 1.1,
               hetero_alpha: float = 0.0) -> Iterator[dict]:
    """Infinite iterator of {"tokens": (B, L)} (or (m, B/m, L) when ``m``).

    ``flip_mask`` (m,) marks workers whose *labels* are corrupted; for LM
    training the label is the next token, so flipping remaps the worker's
    token stream through the label-flip involution.

    ``hetero_alpha`` (> 0, finite; needs ``m``) activates the Dirichlet
    worker-heterogeneity model of ``repro.data.hetero`` on the token
    stream: worker ``i``'s unigram distribution is the shared Zipf law
    reweighted by a per-worker mixture ``pi_i ~ Dirichlet(alpha * 1)``
    over the vocabulary — the LM analogue of label skew (DESIGN.md §13).
    """
    logits = _zipf_logits(vocab, alpha)
    hetero_on = (m is not None and 0.0 < hetero_alpha < np.inf)
    if hetero_on:
        if batch % m:
            raise ValueError(f"batch {batch} not divisible by m={m}")
        from repro.data.hetero import mixture_key, worker_mixtures
        w = worker_mixtures(mixture_key(seed), hetero_alpha, m, vocab)
        wlogits = logits[None, :] + jnp.log(jnp.maximum(w, 1e-30))
    step = 0
    while True:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        if hetero_on:
            toks = jax.random.categorical(
                key, wlogits[:, None, None, :],
                shape=(m, batch // m, seq_len))
            out = {"tokens": toks.astype(jnp.int32)}
        else:
            toks = jax.random.categorical(key, logits,
                                          shape=(batch, seq_len))
            out = {"tokens": toks.astype(jnp.int32)}
            if m is not None:
                out = worker_split(out, m)
        if m is not None and flip_mask is not None:
            flipped = flip_labels(out["tokens"], vocab)
            sel = flip_mask.reshape((m, 1, 1))
            out = {"tokens": jnp.where(sel, flipped, out["tokens"])}
        step += 1
        yield out


def stub_batches(d_model: int, vocab: int, batch: int, seq_len: int, *,
                 seed: int = 0, m: Optional[int] = None,
                 flip_mask=None) -> Iterator[dict]:
    """Infinite iterator of {"embeds": (B, L, d), "labels": (B, L)} for
    stub-frontend archs (frame/patch embeddings are synthetic)."""
    logits = _zipf_logits(vocab)
    step = 0
    while True:
        key = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0x5A17), step)
        k1, k2 = jax.random.split(key)
        emb = 0.1 * jax.random.normal(k1, (batch, seq_len, d_model),
                                      jnp.float32)
        lab = jax.random.categorical(k2, logits, shape=(batch, seq_len)
                                     ).astype(jnp.int32)
        out = {"embeds": emb, "labels": lab}
        if m is not None:
            out = worker_split(out, m)
            if flip_mask is not None:
                flipped = flip_labels(out["labels"], vocab)
                sel = flip_mask.reshape((m, 1, 1))
                out = {"embeds": out["embeds"],
                       "labels": jnp.where(sel, flipped, out["labels"])}
        step += 1
        yield out
