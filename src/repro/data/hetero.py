"""Worker-heterogeneity models: non-IID data for the *honest* workers.

Everything else in ``repro.data`` realizes the paper's Assumption 2.1 —
every honest worker draws i.i.d. from one distribution.  This module
relaxes exactly that assumption (DESIGN.md §13), following the two
standard non-IID models of the Byzantine-ML literature (Data & Diggavi
2020; Karimireddy, He & Jaggi 2022):

* **Dirichlet label skew** (``mode="dirichlet"``) — worker ``i`` draws a
  per-class mixture ``pi_i ~ Dirichlet(alpha * 1)`` once per trial
  (:func:`worker_mixtures`, shape ``(m, n_classes)``) and then samples
  its shard from the shared pool with per-example weight
  ``pi_i[label]`` (:func:`dirichlet_indices`, Gumbel-max selection).
  ``alpha -> 0`` gives near single-class workers, ``alpha -> inf``
  recovers the IID split *bit-for-bit* (the selection is gated on
  :func:`skew_active`, so the inactive branch IS the contiguous
  ``worker_split`` reshape).

* **Teacher-rotation concept shift** (``mode="shift"``) — worker ``i``
  labels its (IID-split) inputs with the teacher evaluated on inputs
  rotated by a per-worker angle ``theta_i`` spread over ``[-shift,
  +shift]`` radians (:func:`shift_angles`, planar rotation of
  coordinate pairs).  The workers disagree about ``P(y | x)`` itself —
  the model family where dissimilarity does not vanish with batch size.
  ``shift = 0`` is bit-for-bit IID.

Both models are parameterized by *traced f32 knobs* (``alpha`` /
``shift``) and use only fixed-shape jax ops, so whole trials stay
``lax.scan``-able and the campaign engine vmaps ``hetero_alpha`` /
``hetero_shift`` exactly like the ``adapt_*`` and ``clip_*`` axes.
The per-trial mixture key and the per-step selection key are derived
with the same salted fold-in scheme on both the engine path (in-scan
``batch_fn``) and the legacy iterator path (:func:`hetero_batches`),
which is what keeps the two bit-identical.

The module also provides the measured-heterogeneity estimator
:func:`zeta_sq` — the inter-worker gradient dissimilarity
``zeta^2 = E_i ||g_i - g_bar||^2`` of the bounded-heterogeneity
assumption that replaces Assumption 2.1 in the non-IID line of work —
which the trainer traces every step (``zeta_sq`` over the ground-truth
honest set, ``zeta_good_sq`` over the defense's live good set).
"""

from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp

from repro.core import tree_utils as tu
from repro.data.pipeline import flip_labels, worker_split

f32 = jnp.float32

# Registered model names — ``Scenario.hetero`` is validated against this
# (program structure for the campaign engine: each mode traces its own
# batch_fn, and "iid" is exactly the pre-heterogeneity path).
HETERO_MODELS = ("iid", "dirichlet", "shift")

# Key salts.  The per-trial mixture key is PRNGKey(seed ^ MIX_SALT); the
# per-step selection key is fold_in(step_key, SEL_SALT) where step_key is
# the data pipeline's fold_in(PRNGKey(seed ^ 0xDA7A), t).  Both the
# engine's in-scan batch_fn and the python iterator derive keys this way
# — single source, bit-identical paths.
MIX_SALT = 0x4E7E
SEL_SALT = 0x5E1E

# Dirichlet concentration is clamped to this range before the sampler
# (concentration 0 and inf are NaN factories); values outside the
# active range never reach the sampler — ``skew_active`` gates them
# onto the exact IID branch first.
ALPHA_MIN, ALPHA_MAX = 1e-3, 1e6


def skew_active(alpha) -> jax.Array:
    """Label skew is on for finite positive ``alpha``; ``alpha <= 0`` and
    ``alpha = inf`` both mean IID — the latter is also the model's own
    limit (Dirichlet(inf) is the uniform mixture), so the sentinel and
    the mathematical limit agree."""
    a = jnp.asarray(alpha, f32)
    return jnp.isfinite(a) & (a > 0)


def shift_active(shift) -> jax.Array:
    return jnp.asarray(shift, f32) != 0


def mixture_key(seed) -> jax.Array:
    """Per-trial key for :func:`worker_mixtures` (``seed`` may be traced —
    the engine's vmapped seed lane)."""
    return jax.random.PRNGKey(seed ^ MIX_SALT)


def worker_mixtures(key, alpha, m: int, n_classes: int) -> jax.Array:
    """``(m, n_classes)`` per-worker class mixtures ``pi_i ~
    Dirichlet(alpha * 1)`` — normalized gammas, so ``alpha`` may be a
    traced scalar (vmap knob).  Inactive ``alpha`` (<= 0 or inf) yields
    the exact uniform mixture.

    Sampled in LOG space (``loggamma`` + logsumexp): at strong skew an
    f32 ``gamma(alpha)`` variate underflows to 0.0 for a large fraction
    of draws (alpha = 1e-3: ~40% all-zero rows), and a zero row would
    silently turn that worker's weighted selection into *uniform*
    sampling — the opposite of the requested skew.  Log-space
    normalization keeps every row's maximum at >= 1/n_classes by
    construction; only genuinely negligible entries flush to zero."""
    a = jnp.asarray(alpha, f32)
    safe = jnp.clip(jnp.where(skew_active(a), a, 1.0), ALPHA_MIN, ALPHA_MAX)
    lg = jax.random.loggamma(key, safe, shape=(m, n_classes), dtype=f32)
    pi = jnp.exp(lg - jax.nn.logsumexp(lg, axis=-1, keepdims=True))
    uniform = jnp.full((m, n_classes), 1.0 / n_classes, f32)
    return jnp.where(skew_active(a), pi, uniform)


def dirichlet_indices(key, labels: jax.Array, weights: jax.Array,
                      m: int, per: int) -> jax.Array:
    """``(m, per)`` pool indices for the label-skew partitioner.

    Slot ``(i, j)`` is a Gumbel-max draw over the pool with log-weight
    ``log pi_i[labels[b]]`` — i.e. ``P(slot picks b) = pi_i[y_b] /
    sum_b' pi_i[y_b']``, the pool marginal reweighted by worker ``i``'s
    mixture.  Sampling is with replacement (the pool is an infinite
    synthetic stream, not a finite dataset), which is what keeps shapes
    static: every worker shard is exactly ``per`` examples regardless
    of how skewed the mixture is.
    """
    logw = jnp.log(jnp.maximum(weights[:, labels], 1e-30))     # (m, B)
    gum = jax.random.gumbel(key, (m, per) + labels.shape, f32)
    return jnp.argmax(logw[:, None, :] + gum, axis=-1).astype(jnp.int32)


def shift_angles(shift, m: int) -> jax.Array:
    """``(m,)`` per-worker rotation angles spread evenly over
    ``[-shift, +shift]`` radians (``shift`` may be traced)."""
    span = 2.0 * jnp.arange(m, dtype=f32) / max(m - 1, 1) - 1.0
    return jnp.asarray(shift, f32) * span


def rotate_pairs(x: jax.Array, theta: jax.Array) -> jax.Array:
    """Planar rotation of consecutive coordinate pairs of ``x`` by
    ``theta`` (broadcast against ``x[..., 0]``); an odd trailing
    coordinate passes through."""
    d = x.shape[-1]
    k = d // 2
    a, b = x[..., 0:2 * k:2], x[..., 1:2 * k:2]
    c, s = jnp.cos(theta)[..., None], jnp.sin(theta)[..., None]
    rot = jnp.stack([a * c - b * s, a * s + b * c], axis=-1)
    rot = rot.reshape(x.shape[:-1] + (2 * k,))
    if 2 * k < d:
        rot = jnp.concatenate([rot, x[..., 2 * k:]], axis=-1)
    return rot


def hetero_worker_batch(task, key, batch: int, m: int, *, mode: str,
                        weights: Optional[jax.Array] = None,
                        alpha=0.0, shift=0.0) -> dict:
    """One worker-split teacher batch ``{"x": (m, B/m, d), "y": (m, B/m)}``
    under a heterogeneity model.

    ``key`` is the step key of the IID pipeline (``fold_in(PRNGKey(seed ^
    0xDA7A), t)``) — the shared pool is ``tasks.teacher_batch(task, key,
    batch)`` for every mode, so an inactive knob reproduces the IID
    split bit-for-bit.  ``alpha``/``shift`` may be traced scalars;
    ``weights`` is the per-trial :func:`worker_mixtures` draw (required
    for ``mode="dirichlet"``).
    """
    from repro.data import tasks   # tasks lazily imports pipeline: no cycle
    if mode not in HETERO_MODELS:
        raise ValueError(f"unknown hetero model {mode!r} "
                         f"(one of {HETERO_MODELS})")
    pool = tasks.teacher_batch(task, key, batch)
    out = worker_split(pool, m)
    if mode == "iid":
        return out
    per = batch // m
    if mode == "dirichlet":
        if weights is None:
            raise ValueError("dirichlet mode needs per-worker mixture "
                             "weights (worker_mixtures)")
        idx_iid = jnp.arange(batch, dtype=jnp.int32).reshape(m, per)
        idx_skew = dirichlet_indices(jax.random.fold_in(key, SEL_SALT),
                                     pool["y"], weights, m, per)
        # row-gather with the IID indices is bit-identical to the reshape,
        # so the inactive branch IS the IID split
        idx = jnp.where(skew_active(alpha), idx_skew, idx_iid)
        return {"x": pool["x"][idx], "y": pool["y"][idx]}
    # mode == "shift": same shards, per-worker rotated-teacher labels
    theta = shift_angles(shift, m)
    xr = rotate_pairs(out["x"], theta[:, None])
    y_rot = tasks.mlp_apply(task.teacher, xr).argmax(-1).astype(jnp.int32)
    y = jnp.where(shift_active(shift), y_rot, out["y"])
    return {"x": out["x"], "y": y}


def hetero_batches(task, batch: int, *, mode: str, alpha=0.0, shift=0.0,
                   seed: int = 0, m: int, n_classes: Optional[int] = None,
                   flip_mask=None) -> Iterator[dict]:
    """Python-iterator twin of the engine's in-scan hetero ``batch_fn``
    (the legacy ``Trainer`` path) — same key schedule, same selection,
    bit-identical batches.  ``flip_mask`` applies the label-flip data
    attack to the marked workers' shards, as in ``teacher_batches``."""
    n_classes = task.n_classes if n_classes is None else n_classes
    weights = None
    if mode == "dirichlet":
        weights = worker_mixtures(mixture_key(seed), alpha, m, n_classes)
    step = 0
    while True:
        key = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0xDA7A), step)
        out = hetero_worker_batch(task, key, batch, m, mode=mode,
                                  weights=weights, alpha=alpha, shift=shift)
        if flip_mask is not None:
            flipped = flip_labels(out["y"], n_classes)
            sel = flip_mask.reshape((m, 1))
            out = {"x": out["x"], "y": jnp.where(sel, flipped, out["y"])}
        step += 1
        yield out


def zeta_sq(grads, mask: jax.Array) -> jax.Array:
    """Measured inter-worker dissimilarity ``zeta^2 = E_{i in mask}
    ||g_i - g_bar_mask||^2`` — the bounded-heterogeneity constant of the
    non-IID assumption (Data & Diggavi 2020; Karimireddy et al. 2022)
    estimated from this step's stacked gradients.  O(m d), no Gram, no
    flattening (model-axis sharding of large leaves survives)."""
    return tu.tree_dissimilarity(grads, mask)
