"""Planted-saddle task family: the saddle-escape verification testbed.

The paper's headline theorem is second-order — SafeguardSGD *escapes
saddle points* and reaches approximate local minima under Byzantine
workers — but the teacher-student benchmark only measures accuracy.
This module provides a synthetic non-convex family whose saddle
structure is *planted* and therefore fully analytic (DESIGN.md §14):
gradients, the negative-curvature directions, and the escape predicate
are all closed-form and O(k d), so theorem-level assertions (escape
within a predicted step budget) become ordinary tests.

Two task kinds, both built from ``k`` orthonormal planted directions
``q_1..q_k`` (a seeded QR draw) and a positive-definite bulk:

* ``saddle_quad`` (k = 1) — the single-saddle ``x^T H x`` family:

      f(x) = -(gap/2) (q_1 . x)^2 + (lam/2) ||x - P x||^2

  One controlled negative eigenvalue ``lambda_min = -gap`` with known
  escape direction ``q_1``; the origin is a strict saddle.  Escape =
  ``|q_1 . x| >= QUAD_ESCAPE_RADIUS`` (an O(1) displacement — the pure
  quadratic has no basin, so the radius is a fixed constant).

* ``saddle_chain`` (k = CHAIN_K) — octopus-style chained saddles: each
  planted direction carries a double well with geometrically decaying
  curvature gap,

      f(x) = sum_j [ -(gap_j/2) u_j^2 + (beta/4) u_j^4 ]
             + (lam/2) ||x - P x||^2,      u_j = q_j . x,
      gap_j = gap * rho^j,  rho < 1,

  so the origin is a strict saddle with ``k`` negative directions and
  the iterate escapes them *in sequence* — the j-th stage is
  exponentially slower (escape time ~ 1/gap_j), emulating the chained
  passage of Du et al.'s octopus through a sequence of near-saddle
  regions while keeping every quantity separable and exact.  Stage j
  escapes at ``|u_j| >= sqrt(gap_j / (3 beta))`` — exactly the
  inflection where the planted Rayleigh quotient turns non-negative, so
  ``escaped(x)  <=>  min_eig_proxy(x) >= 0`` by construction.

Analytics exposed (all scan/vmap-safe; ``gap`` and ``noise_r`` may be
traced scalars, which is what lets the campaign engine vmap
``saddle_gap`` / ``noise_r`` exactly like ``hetero_alpha``):

* :func:`saddle_value` / :func:`saddle_grad` — closed-form f and grad;
* :func:`min_eig_proxy` — Rayleigh quotient ``min_j q_j^T H(x) q_j``
  along the planted directions, O(k d), no Hessian materialization
  (``dw_j''(u_j) = -gap_j + 3 beta u_j^2``; the bulk never contributes
  because ``P q_j = q_j``);
* :func:`escaped` — the escape predicate, invariant under the family's
  symmetry group (reflections ``u_j -> -u_j`` across any planted
  hyperplane, and any rotation of the bulk complement);
* :func:`escape_budget` — the predicted escape-step budget from the
  power-iteration argument of the Theorem (DESIGN.md §14).

Stochastic gradients use the linear noise model: worker ``i`` sees

    loss_i(x) = f(x) + noise_r * mean_b (eps_{i,b} . x),

so ``g_i = grad f(x) + noise_r * mean_b eps_{i,b}`` with eps ~ N(0, I)
— zero-mean over seeds (tested) and independent of x.  Under this model
Byzantine SVRG (Khanduri et al., arXiv:1912.04531) reduces *exactly* to
anchored noise: the control variate ``g_i(x) - g_i(x_a)`` cancels the
noise term, leaving the reference batch's noise, fixed until the next
anchor refresh.  :func:`anchor_step` implements that reduction — the
``vr_period`` knob (0/1 = plain SGD, p >= 2 = refresh every p steps,
reference noise scaled by :data:`VR_REF_SCALE`) is a vmap axis like
every other knob.

The per-step key schedule is the data pipeline's
``fold_in(PRNGKey(seed ^ 0xDA7A), t)`` — :func:`saddle_batches` is the
python-iterator twin of the engine's in-scan batch_fn (same keys,
bit-identical batches), mirroring ``hetero.hetero_batches``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

f32 = jnp.float32

# Registered saddle task names — ``Scenario.task`` is validated against
# TASK_MODELS ("teacher" + these); the kind is program structure for the
# campaign engine (each kind traces its own loss/batch_fn).
SADDLE_TASKS = ("saddle_quad", "saddle_chain")

CHAIN_K = 3          # planted directions of the chained family
CHAIN_RHO = 0.5      # per-stage curvature-gap decay (gap_j = gap * rho^j)
CHAIN_BETA = 1.0     # quartic coefficient of the double wells
BULK_LAM = 1.0       # positive curvature of the bulk complement
# the pure quadratic has no basin boundary, so its escape radius is a
# fixed O(1) displacement along the planted direction
QUAD_ESCAPE_RADIUS = 1.0
# SVRG reference-batch noise scale: the anchored reference gradient is
# computed on a 4x batch, so its noise is halved (1/sqrt(4))
VR_REF_SCALE = 0.5


@dataclasses.dataclass(frozen=True)
class SaddleTask:
    """Static (program-structure) part of a planted-saddle task; the
    curvature gap and noise radius stay *traced knobs* so they can be
    vmapped campaign axes."""
    d: int
    kind: str                 # "saddle_quad" | "saddle_chain"
    k: int                    # number of planted escape directions
    beta: float               # quartic coefficient (0 => pure quadratic)
    rho: float                # per-stage gap decay
    lam: float                # bulk positive curvature
    seed: int
    dirs: jax.Array           # (k, d) orthonormal planted directions


def make_saddle_task(d: int, kind: str, seed: int = 0) -> SaddleTask:
    """Build the static task: ``k`` orthonormal planted directions from a
    seeded QR draw (the saddle is *planted*, not axis-aligned)."""
    if kind not in SADDLE_TASKS:
        raise ValueError(f"unknown saddle task {kind!r} "
                         f"(one of {SADDLE_TASKS})")
    k = 1 if kind == "saddle_quad" else CHAIN_K
    if d < k + 1:
        raise ValueError(f"saddle task needs d >= k+1 (= {k + 1}), got {d}")
    g = jax.random.normal(jax.random.PRNGKey(seed ^ 0x5ADD), (d, k), f32)
    q, _ = jnp.linalg.qr(g)                      # (d, k) orthonormal cols
    beta = 0.0 if kind == "saddle_quad" else CHAIN_BETA
    return SaddleTask(d=d, kind=kind, k=k, beta=beta, rho=CHAIN_RHO,
                      lam=BULK_LAM, seed=seed, dirs=q.T)


def stage_gaps(task: SaddleTask, gap) -> jax.Array:
    """``(k,)`` per-stage curvature gaps ``gap * rho^j`` (``gap`` may be
    traced).  The largest is stage 0: ``lambda_min(H(0)) = -gap``."""
    decay = jnp.asarray(task.rho, f32) ** jnp.arange(task.k, dtype=f32)
    return jnp.asarray(gap, f32) * decay


def _planted(task: SaddleTask, x: jax.Array) -> jax.Array:
    """``u_j = q_j . x`` — the planted coordinates, shape (k,)."""
    return task.dirs @ x


def saddle_value(task: SaddleTask, x: jax.Array, gap) -> jax.Array:
    u = _planted(task, x)
    gaps = stage_gaps(task, gap)
    wells = (-0.5 * gaps * u ** 2 + 0.25 * task.beta * u ** 4).sum()
    bulk = x - task.dirs.T @ u                   # (I - P) x
    return wells + 0.5 * task.lam * (bulk ** 2).sum()


def saddle_grad(task: SaddleTask, x: jax.Array, gap) -> jax.Array:
    """Closed-form gradient (the property tests pin it against
    ``jax.grad(saddle_value)`` to f32 tolerance)."""
    u = _planted(task, x)
    gaps = stage_gaps(task, gap)
    dw = -gaps * u + task.beta * u ** 3          # (k,) well derivatives
    bulk = x - task.dirs.T @ u
    return task.dirs.T @ dw + task.lam * bulk


def min_eig_proxy(task: SaddleTask, x: jax.Array, gap) -> jax.Array:
    """Rayleigh quotient of the Hessian along the planted directions,
    ``min_j q_j^T H(x) q_j = min_j (-gap_j + 3 beta u_j^2)`` — O(k d),
    never materializes H.  At the saddle this is exactly the planted
    ``lambda_min = -gap``; it brackets the true minimum eigenvalue from
    above everywhere (Rayleigh) and crosses 0 exactly when every chain
    stage passes its inflection."""
    u = _planted(task, x)
    gaps = stage_gaps(task, gap)
    return (-gaps + 3.0 * task.beta * u ** 2).min()


def escape_radii(task: SaddleTask, gap) -> jax.Array:
    """``(k,)`` per-stage escape radii.  Chain: ``sqrt(gap_j/(3 beta))``
    (the inflection of well j, where its curvature turns non-negative);
    quad: the fixed :data:`QUAD_ESCAPE_RADIUS`."""
    gaps = stage_gaps(task, gap)
    if task.beta == 0.0:
        return jnp.full((task.k,), QUAD_ESCAPE_RADIUS, f32)
    return jnp.sqrt(gaps / (3.0 * task.beta))


def escaped(task: SaddleTask, x: jax.Array, gap) -> jax.Array:
    """True once every planted stage has left its saddle:
    ``all_j |u_j| >= r_j``.  Invariant under the family's symmetry group
    (per-stage reflections ``u_j -> -u_j``, bulk rotations)."""
    u = _planted(task, x)
    return (jnp.abs(u) >= escape_radii(task, gap)).all()


def escape_budget(task: SaddleTask, gap: float, lr: float,
                  u0: float, slack: float = 3.0) -> int:
    """Predicted escape-step budget from the Theorem's power-iteration
    argument (DESIGN.md §14): along stage j the deterministic dynamics
    near the saddle are ``u <- (1 + lr * gap_j) u``, so growing from the
    noise floor ``u0`` to the escape radius ``r_j`` takes
    ``log(r_j / u0) / log(1 + lr * gap_j)`` steps.  Stages escape
    concurrently, so the budget is the *slowest* stage (the smallest
    gap), times ``slack`` for the Byzantine eviction phase and the
    stochastic noise floor."""
    gaps = [gap * task.rho ** j for j in range(task.k)]
    if task.beta == 0.0:
        radii = [QUAD_ESCAPE_RADIUS] * task.k
    else:
        radii = [math.sqrt(g / (3.0 * task.beta)) for g in gaps]
    worst = max(math.log(max(r / u0, 1.0 + 1e-6)) / math.log1p(lr * g)
                for g, r in zip(gaps, radii))
    return int(math.ceil(slack * worst))


# --------------------------------------------------------------------------
# Stochastic-gradient model
# --------------------------------------------------------------------------

def x_init(task: SaddleTask) -> dict:
    """Start *exactly at the planted saddle* — the hard case the theorem
    is about: the gradient is 0 there, only noise can initiate escape."""
    return {"x": jnp.zeros((task.d,), f32)}


def make_saddle_loss(task: SaddleTask, gap, noise_r):
    """``loss(params, worker_batch) -> scalar`` with the linear noise
    model: ``f(x) + noise_r * mean_b (eps_b . x)``.  ``value_and_grad``
    therefore yields ``g_i = grad f + noise_r * mean_b eps_{i,b}`` —
    gradient noise with zero mean and covariance independent of x.
    ``gap`` / ``noise_r`` may be traced (vmap knobs)."""
    def loss(params, batch):
        x = params["x"]
        noise = (batch["eps"] @ x).mean()
        return saddle_value(task, x, gap) + jnp.asarray(noise_r, f32) * noise
    return loss


def anchor_step(t, period) -> jax.Array:
    """Byzantine-SVRG anchoring under the linear noise model: the step
    whose key the batch is drawn from.  ``period <= 1`` is plain SGD
    (fresh noise every step); ``period >= 2`` re-draws only at anchor
    refreshes ``t - t % period`` — the exact reduction of the SVRG
    control variate for x-independent noise.  Both args may be traced."""
    t = jnp.asarray(t, jnp.int32)
    p = jnp.asarray(period, jnp.int32)
    return jnp.where(p >= 2, t - t % jnp.maximum(p, 1), t)


def vr_scale(period) -> jax.Array:
    """Noise scale of the (4x larger) SVRG reference batch; 1 when
    variance reduction is off."""
    p = jnp.asarray(period, jnp.int32)
    return jnp.where(p >= 2, jnp.asarray(VR_REF_SCALE, f32),
                     jnp.asarray(1.0, f32))


def saddle_batch(task: SaddleTask, key, batch: int, m: int,
                 scale=1.0) -> dict:
    """One worker-split noise batch ``{"eps": (m, B/m, d)}``; ``scale``
    (traced) multiplies the draw — the SVRG reference-batch factor."""
    per = batch // m
    eps = jax.random.normal(key, (m, per, task.d), f32)
    return {"eps": jnp.asarray(scale, f32) * eps}


def step_key(seed, t) -> jax.Array:
    """The data pipeline's step key — same salt/fold-in scheme as
    ``tasks.teacher_batches`` so both paths share one schedule."""
    return jax.random.fold_in(jax.random.PRNGKey(seed ^ 0xDA7A), t)


def saddle_batches(task: SaddleTask, batch: int, *, seed: int = 0,
                   m: int, vr_period: int = 0) -> Iterator[dict]:
    """Python-iterator twin of the engine's in-scan saddle batch_fn (the
    legacy ``Trainer`` path) — same key schedule, same anchoring,
    bit-identical batches."""
    t = 0
    while True:
        ta = int(anchor_step(t, vr_period))
        yield saddle_batch(task, step_key(seed, ta), batch, m,
                           scale=vr_scale(vr_period))
        t += 1


def make_probe(task: SaddleTask, gap):
    """The second-order trace lane (DESIGN.md §14): a pure function of
    the current params the trainer traces every step next to loss /
    zeta_sq.  ``true_grad_norm`` is the theorem's ||grad f(x)|| (the
    *analytic* gradient, not the aggregated stochastic one),
    ``min_eig_proxy`` the planted Rayleigh quotient, ``escaped`` the
    predicate as f32 — the engine derives ``escape_step`` (first step it
    fires) from this trace."""
    def probe(params):
        x = params["x"]
        g = saddle_grad(task, x, gap)
        return {
            "true_grad_norm": jnp.sqrt((g ** 2).sum()),
            "min_eig_proxy": min_eig_proxy(task, x, gap),
            "escaped": escaped(task, x, gap).astype(f32),
        }
    return probe


def first_escape_step(escaped_trace) -> int:
    """First step the escape predicate fired, else -1 (the 'never
    escapes' sentinel of the stall assertions)."""
    esc = np.asarray(escaped_trace)
    hits = np.flatnonzero(esc > 0.5)
    return int(hits[0]) if hits.size else -1
