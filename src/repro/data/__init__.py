from repro.data.pipeline import (   # noqa: F401
    lm_batches, stub_batches, worker_split, flip_labels)
from repro.data.tasks import (      # noqa: F401
    TeacherTask, make_teacher_task, teacher_batches)
from repro.data.hetero import (     # noqa: F401
    HETERO_MODELS, hetero_batches, hetero_worker_batch, worker_mixtures,
    zeta_sq)
from repro.data.saddle import (     # noqa: F401
    SADDLE_TASKS, SaddleTask, escape_budget, escaped, make_probe,
    make_saddle_loss, make_saddle_task, min_eig_proxy, saddle_batches,
    saddle_grad, saddle_value)
