from repro.data.pipeline import (   # noqa: F401
    lm_batches, stub_batches, worker_split, flip_labels)
from repro.data.tasks import (      # noqa: F401
    TeacherTask, make_teacher_task, teacher_batches)
