"""Teacher-student classification task for the attack-grid benchmarks.

The paper's experimental protocol (ResNet-20 on CIFAR) needs a real
dataset; offline we substitute a *non-convex, learnable* task with a known
optimum: inputs x ~ N(0, I_d), labels from a fixed randomly-initialized
teacher MLP.  The student is a same-shape MLP trained with cross-entropy —
non-convex, saddle-rich, and the test accuracy of honest SGD gives the
"ideal accuracy" reference the paper reports.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class TeacherTask:
    d_in: int
    d_hidden: int
    n_classes: int
    teacher: dict
    seed: int


def _mlp_init(key, d_in, d_hidden, n_classes, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {
        "w1": scale * jax.random.normal(k1, (d_in, d_hidden), f32)
        / jnp.sqrt(d_in),
        "b1": jnp.zeros((d_hidden,), f32),
        "w2": scale * jax.random.normal(k2, (d_hidden, n_classes), f32)
        / jnp.sqrt(d_hidden),
        "b2": jnp.zeros((n_classes,), f32),
    }


def mlp_apply(params, x):
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def mlp_loss(params, batch):
    logits = mlp_apply(params, batch["x"])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    return (lse - gold).mean()


def mlp_accuracy(params, batch):
    logits = mlp_apply(params, batch["x"])
    return (logits.argmax(-1) == batch["y"]).mean()


def make_teacher_task(d_in: int = 32, d_hidden: int = 64,
                      n_classes: int = 10, seed: int = 0) -> TeacherTask:
    teacher = _mlp_init(jax.random.PRNGKey(seed ^ 0x7EAC), d_in, d_hidden,
                        n_classes, scale=2.0)
    return TeacherTask(d_in, d_hidden, n_classes, teacher, seed)


def student_init(task: TeacherTask, seed: int = 1):
    return _mlp_init(jax.random.PRNGKey(seed), task.d_in, task.d_hidden,
                     task.n_classes)


def teacher_batch(task: TeacherTask, key, batch: int):
    kx, = jax.random.split(key, 1)
    x = jax.random.normal(kx, (batch, task.d_in), f32)
    y = mlp_apply(task.teacher, x).argmax(-1).astype(jnp.int32)
    return {"x": x, "y": y}


def teacher_batches(task: TeacherTask, batch: int, *, seed: int = 0,
                    m: Optional[int] = None,
                    flip_mask=None) -> Iterator[dict]:
    from repro.data.pipeline import worker_split, flip_labels
    step = 0
    while True:
        key = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0xDA7A), step)
        out = teacher_batch(task, key, batch)
        if m is not None:
            out = worker_split(out, m)
            if flip_mask is not None:
                flipped = flip_labels(out["y"], task.n_classes)
                sel = flip_mask.reshape((m, 1))
                out = {"x": out["x"], "y": jnp.where(sel, flipped, out["y"])}
        step += 1
        yield out
