"""Model assembly: init / forward / loss / prefill / decode for every
assigned architecture, driven entirely by ``ModelConfig``.

Layers are stacked along a leading layer axis and executed with
``jax.lax.scan`` (constant compile time in depth — critical for the
88-layer dry runs).  Heterogeneous stacks are split into homogeneous
scan groups:

  * dense / vlm / audio:      one scan over identical attention blocks;
  * moe (granite-moe):        one scan over attention+MoE blocks;
  * moe (deepseek-v2):        layer 0 (dense FFN) unrolled, scan over the
                              remaining MLA+MoE blocks;
  * ssm (mamba2):             one scan over SSD blocks;
  * hybrid (recurrentgemma):  scan over (rec, rec, attn) super-blocks plus
                              unrolled trailing rec layers (26 = 3*8 + 2).

The decode cache mirrors the same grouping so it scans along with the
parameters.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

f32 = jnp.float32


# ==========================================================================
# Parameter initialization
# ==========================================================================

def _norm_init(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((d,), cfg.param_dtype)}
    return {"scale": jnp.ones((d,), cfg.param_dtype),
            "bias": jnp.zeros((d,), cfg.param_dtype)}


def _layer_kind(cfg: ModelConfig, idx: int) -> str:
    if cfg.ssm:
        return "ssm"
    if cfg.hybrid:
        return "attn" if idx % 3 == 2 else "rec"
    if cfg.n_experts > 0:
        if idx < cfg.first_k_dense:
            return "mla_dense" if cfg.use_mla else "attn_dense_wide"
        return "mla_moe" if cfg.use_mla else "attn_moe"
    return "attn"


def _layer_init(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {"ln": _norm_init(cfg),
                "mixer": L.mamba2_block_init(ks[0], cfg)}
    if kind == "rec":
        return {"ln1": _norm_init(cfg),
                "rec": L.rglru_block_init(ks[0], cfg),
                "ln2": _norm_init(cfg),
                "mlp": L.mlp_init(ks[1], cfg.mlp, cfg.d_model, cfg.d_ff,
                                  cfg.param_dtype)}
    attn_init = L.mla_block_init if kind.startswith("mla") else L.attn_block_init
    p = {"ln1": _norm_init(cfg),
         "attn": attn_init(ks[0], cfg),
         "ln2": _norm_init(cfg)}
    if kind in ("attn", "attn_dense_wide", "mla_dense"):
        d_ff = cfg.d_ff_dense if kind in ("attn_dense_wide", "mla_dense") \
            else cfg.d_ff
        p["mlp"] = L.mlp_init(ks[1], cfg.mlp, cfg.d_model, d_ff,
                              cfg.param_dtype)
    else:
        p["moe"] = L.moe_init(ks[1], cfg)
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.n_layers + 3)
    params: Dict[str, Any] = {}

    if not cfg.embed_stub:
        params["embed"] = (0.02 * jax.random.normal(
            keys[-1], (cfg.vocab_size, cfg.d_model))).astype(cfg.param_dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = (0.02 * jax.random.normal(
            keys[-2], (cfg.d_model, cfg.vocab_size))).astype(cfg.param_dtype)
    elif cfg.embed_stub:
        raise ValueError("tie_embeddings requires an input embedding table")
    params["final_norm"] = _norm_init(cfg)

    if cfg.hybrid:
        n_super, n_tail = cfg.n_layers // 3, cfg.n_layers % 3
        supers = []
        for s in range(n_super):
            k3 = jax.random.split(keys[s], 3)
            supers.append({
                "rec1": _layer_init(k3[0], cfg, "rec"),
                "rec2": _layer_init(k3[1], cfg, "rec"),
                "attn": _layer_init(k3[2], cfg, "attn"),
            })
        params["super_blocks"] = _stack(supers)
        params["tail_blocks"] = [
            _layer_init(keys[n_super + t], cfg, "rec") for t in range(n_tail)]
        return params

    kinds = [_layer_kind(cfg, i) for i in range(cfg.n_layers)]
    n_pre = cfg.first_k_dense if cfg.n_experts > 0 else 0
    params["pre_blocks"] = [
        _layer_init(keys[i], cfg, kinds[i]) for i in range(n_pre)]
    params["blocks"] = _stack([
        _layer_init(keys[i], cfg, kinds[i])
        for i in range(n_pre, cfg.n_layers)])
    return params


def init_abstract(cfg: ModelConfig, seed: int = 0):
    """Parameter ShapeDtypeStructs without allocating (for the dry run)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.key(seed))


# ==========================================================================
# Cache initialization
# ==========================================================================

def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Decode cache pytree; grouping mirrors the parameter grouping."""
    dt = cfg.dtype

    def one(kind):
        if kind == "ssm":
            return L.mamba2_cache_init(cfg, batch, dt)
        if kind == "rec":
            return L.rglru_cache_init(cfg, batch, dt)
        if cfg.use_mla:
            return L.mla_cache_init(cfg, batch, max_seq, dt)
        return L.attn_cache_init(cfg, batch, max_seq, dt)

    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.hybrid:
        n_super, n_tail = cfg.n_layers // 3, cfg.n_layers % 3
        cache["super_blocks"] = _stack([
            {"rec1": one("rec"), "rec2": one("rec"), "attn": one("attn")}
            for _ in range(n_super)])
        cache["tail_blocks"] = [one("rec") for _ in range(n_tail)]
        return cache

    kinds = [_layer_kind(cfg, i) for i in range(cfg.n_layers)]
    n_pre = cfg.first_k_dense if cfg.n_experts > 0 else 0
    cache["pre_blocks"] = [one(kinds[i]) for i in range(n_pre)]
    cache["blocks"] = _stack([one(kinds[i])
                              for i in range(n_pre, cfg.n_layers)])
    return cache


# ==========================================================================
# Blocks
# ==========================================================================

def _apply_layer(p, cfg, kind, x, positions, cache, cache_pos,
                 max_seq: int = 0):
    """Pre-norm residual layer.  Returns (x, new_cache, aux).

    ``cache`` is the decode-time state (None during train/prefill);
    ``max_seq > 0`` marks prefill: attention layers then emit ring-packed
    caches of that size (recurrent layers always emit their final state).
    """
    # anchor the residual stream: replicated over the model axis
    x = L.constrain(x, L._U, L._U, None)
    aux = jnp.zeros((), f32)
    if kind == "ssm":
        h = L.apply_norm(p["ln"], x, cfg.norm)
        out, new_cache = L.mamba2_block_apply(p["mixer"], cfg, h, cache=cache)
        return x + out, new_cache, aux
    if kind == "rec":
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        out, new_cache = L.rglru_block_apply(p["rec"], cfg, h, cache=cache)
        x = x + out
        h = L.apply_norm(p["ln2"], x, cfg.norm)
        x = x + L.mlp_apply(p["mlp"], cfg.mlp, h)
        return x, new_cache, aux

    h = L.apply_norm(p["ln1"], x, cfg.norm)
    if kind.startswith("mla"):
        out, new_cache = L.mla_block_apply(
            p["attn"], cfg, h, positions=positions, cache=cache,
            cache_pos=cache_pos, max_seq=max_seq)
    else:
        out, new_cache = L.attn_block_apply(
            p["attn"], cfg, h, positions=positions, cache=cache,
            cache_pos=cache_pos, max_seq=max_seq)
    x = x + out
    h = L.apply_norm(p["ln2"], x, cfg.norm)
    if "moe" in p:
        out, aux = L.moe_apply(p["moe"], cfg, h)
    else:
        out = L.mlp_apply(p["mlp"], cfg.mlp, h)
    return x + out, new_cache, aux


# ==========================================================================
# Forward
# ==========================================================================

def _default_positions(cfg, B, Lq, offset):
    base = jnp.arange(Lq)[None, :] + offset          # (1, L) or (B, L)
    base = jnp.broadcast_to(base, (B, Lq))
    if cfg.pos == "mrope":
        return jnp.broadcast_to(base[None], (3, B, Lq))
    return base


def forward(params, cfg: ModelConfig, inputs, *, positions=None,
            cache=None, mode: str = "train", max_seq: int = 0,
            remat: bool = True):
    """Run the model.

    inputs: tokens (B, L) int32, or embeddings (B, L, d) for stub-frontend
    archs.
    mode:
      * "train"   — full sequence, no cache in or out;
      * "prefill" — full sequence; returns a freshly built decode cache of
        capacity ``max_seq`` (ring-packed for attention layers, final state
        for recurrent layers);
      * "decode"  — L == 1, ``cache`` required, returns the updated cache.

    Returns (logits (B, L, V), new_cache_or_None, aux_dict).
    """
    if mode == "decode" and cache is None:
        raise ValueError("decode needs a cache")
    if mode == "prefill" and max_seq <= 0:
        raise ValueError("prefill needs max_seq")
    if mode != "prefill":
        max_seq = 0
    want_cache = mode in ("prefill", "decode")

    if cfg.embed_stub:
        x = inputs.astype(cfg.dtype)
        B, Lq = x.shape[0], x.shape[1]
    else:
        B, Lq = inputs.shape
        x = params["embed"][inputs].astype(cfg.dtype)

    cache_pos = cache["pos"] if cache is not None else jnp.zeros((), jnp.int32)
    if positions is None:
        positions = _default_positions(cfg, B, Lq, cache_pos)
    if cfg.pos == "sinusoidal":
        pos_emb = L.sinusoidal_embedding(
            positions if positions.ndim == 2 else positions[0], cfg.d_model)
        x = x + pos_emb.astype(cfg.dtype)

    aux_total = jnp.zeros((), f32)
    new_cache: Optional[Dict[str, Any]] = {} if want_cache else None
    # activation checkpointing: in train mode, each scanned layer saves
    # only its (bf16) input and recomputes internals in the backward pass —
    # the standard memory/compute trade at these batch sizes, and it also
    # prevents XLA from stashing f32 flash-attention internals per layer.
    use_remat = remat and mode == "train"

    def run(p, kind, xc, c):
        return _apply_layer(p, cfg, kind, xc, positions, c, cache_pos,
                            max_seq)

    if cfg.hybrid:
        def super_body(carry, p, c):
            xc, aux = carry
            xc, nc1, a1 = run(p["rec1"], "rec", xc,
                              c["rec1"] if c is not None else None)
            xc, nc2, a2 = run(p["rec2"], "rec", xc,
                              c["rec2"] if c is not None else None)
            xc, nc3, a3 = run(p["attn"], "attn", xc,
                              c["attn"] if c is not None else None)
            return ((xc, aux + a1 + a2 + a3),
                    {"rec1": nc1, "rec2": nc2, "attn": nc3})

        if cache is not None:
            fn = lambda carry, xs: super_body(carry, xs[0], xs[1])
            xs = (params["super_blocks"], cache["super_blocks"])
        else:
            fn = lambda carry, xs: super_body(carry, xs, None)
            xs = params["super_blocks"]
        if use_remat:
            fn = jax.checkpoint(fn)
        (x, aux_total), new_super = jax.lax.scan(fn, (x, aux_total), xs)
        new_tail = []
        for t, tp in enumerate(params["tail_blocks"]):
            tc = cache["tail_blocks"][t] if cache is not None else None
            x, ntc, a = run(tp, "rec", x, tc)
            aux_total = aux_total + a
            new_tail.append(ntc)
        if want_cache:
            new_cache["super_blocks"] = new_super
            new_cache["tail_blocks"] = new_tail
    else:
        kinds = [_layer_kind(cfg, i) for i in range(cfg.n_layers)]
        n_pre = cfg.first_k_dense if cfg.n_experts > 0 else 0
        new_pre = []
        for i in range(n_pre):
            pc = cache["pre_blocks"][i] if cache is not None else None
            x, npc, a = run(params["pre_blocks"][i], kinds[i], x, pc)
            aux_total = aux_total + a
            new_pre.append(npc)
        kind = kinds[n_pre] if cfg.n_layers > n_pre else "attn"

        def block_body(carry, p, c):
            xc, aux = carry
            xc, nc, a = run(p, kind, xc, c)
            return (xc, aux + a), nc

        if cache is not None:
            fn = lambda carry, xs: block_body(carry, xs[0], xs[1])
            xs = (params["blocks"], cache["blocks"])
        else:
            fn = lambda carry, xs: block_body(carry, xs, None)
            xs = params["blocks"]
        if use_remat:
            fn = jax.checkpoint(fn)
        (x, aux_total), new_blocks = jax.lax.scan(fn, (x, aux_total), xs)
        if want_cache:
            new_cache["pre_blocks"] = new_pre
            new_cache["blocks"] = new_blocks

    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("bld,dv->blv", x, head.astype(x.dtype),
                        preferred_element_type=f32)
    logits = L.constrain(logits, L._U, L._U, L._mdl(cfg.vocab_size))

    if want_cache:
        new_cache["pos"] = cache_pos + Lq
    aux = {"moe_aux": aux_total}
    return logits, new_cache, aux


# ==========================================================================
# Loss / train step building blocks
# ==========================================================================

def cross_entropy(logits, targets, mask=None):
    """Mean next-token CE in f32.  logits (B, L, V), targets (B, L).

    The gold logit is extracted with an iota-compare + masked reduction
    rather than ``take_along_axis``: a gather along a vocab axis that is
    sharded over the ``model`` mesh axis would force XLA to all-gather the
    full logits (hundreds of GB at the production shapes); the compare
    form stays elementwise + local-reduce + tiny all-reduce.
    """
    logits = logits.astype(f32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.where(vocab_iota == targets[..., None], logits, 0.0).sum(-1)
    nll = lse - gold
    if mask is None:
        return nll.mean()
    maskf = mask.astype(f32)
    return (nll * maskf).sum() / jnp.maximum(maskf.sum(), 1.0)


def loss_fn(params, cfg: ModelConfig, batch, *, positions=None):
    """Next-token LM loss.  batch: {"tokens": (B, L)} or, for stub
    frontends, {"embeds": (B, L, d), "labels": (B, L)}."""
    if cfg.embed_stub:
        inputs, labels = batch["embeds"], batch["labels"]
    else:
        inputs, labels = batch["tokens"], batch["tokens"]
    logits, _, aux = forward(params, cfg, inputs, positions=positions,
                             mode="train")
    loss = cross_entropy(logits[:, :-1], labels[:, 1:])
    if cfg.n_experts > 0:
        loss = loss + cfg.router_aux_coef * aux["moe_aux"] / cfg.n_layers
    return loss


def prefill(params, cfg: ModelConfig, inputs, *, max_seq: int,
            positions=None):
    """Process a full prompt, returning (last-token logits, decode cache)."""
    logits, new_cache, _ = forward(params, cfg, inputs, positions=positions,
                                   mode="prefill", max_seq=max_seq)
    return logits[:, -1], new_cache


def decode_step(params, cfg: ModelConfig, token_or_embed, cache, *,
                positions=None):
    """One decode step.  token (B, 1) int32 or embed (B, 1, d)."""
    logits, new_cache, _ = forward(params, cfg, token_or_embed,
                                   positions=positions, cache=cache,
                                   mode="decode")
    return logits[:, -1], new_cache
