"""Neural-net layers for the assigned architecture pool.

Pure functions over parameter pytrees (no flax/haiku dependency — params
are nested dicts of ``jnp`` arrays so they stack cleanly across the worker
axis for the safeguard and across the layer axis for ``lax.scan``).

Implemented temporal-mixing families:
  * GQA/MQA/MHA attention, full or sliding-window, RoPE (standard, partial,
    M-RoPE) or sinusoidal positions — dense, VLM, audio archs;
  * MLA (multi-head latent attention, DeepSeek-V2) with the compressed
    ``c_kv``/``k_rope`` decode cache;
  * RG-LRU recurrent blocks (RecurrentGemma/Griffin);
  * Mamba-2 SSD (state-space duality) with chunked training scan and O(1)
    decode state.

Channel mixing: SwiGLU / GeGLU / GELU MLPs and a capacity-based
expert-parallel MoE (argsort dispatch — no (tokens, E, C) one-hot tensor).

All matmuls accumulate in float32 (``preferred_element_type``) and softmax
/ norms run in float32 regardless of the compute dtype.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

f32 = jnp.float32

# --------------------------------------------------------------------------
# Activation-sharding constraints (enabled by the launch layer only).
#
# Megatron-style TP anchoring: the residual stream is replicated over the
# ``model`` mesh axis; head / ffn / expert dims inside a layer are sharded
# over it.  ``vmap(..., spmd_axis_name=<data axes>)`` in the trainer then
# extends every constraint with the worker axis, which is what keeps the
# per-worker backward pass sharded (XLA's propagation alone drops it inside
# the layer scan and replicates multi-GiB buffers).  Batch/seq dims are
# left UNCONSTRAINED so serving paths can shard them over data.
# --------------------------------------------------------------------------

from jax.sharding import PartitionSpec as _P

_ACT = {"on": False, "model_n": 1, "anchor_residual": True}
_U = _P.UNCONSTRAINED


def enable_activation_sharding(on: bool = True, model_n: int = 1,
                               anchor_residual: bool = True):
    """``anchor_residual``: pin the residual stream (and per-layer block
    outputs) to model-axis replication (Megatron TP convention) — required
    for the vmapped per-worker train path, where propagation otherwise
    drops the worker sharding.  Serving paths (no worker vmap) run better
    *without* the anchor: XLA then keeps the layer carry and all per-token
    ops sequence-sharded and only gathers K/V for attention (a de-facto
    sequence-parallel schedule; see EXPERIMENTS.md §Perf, deepseek-coder
    prefill hillclimb)."""
    _ACT["on"] = on
    _ACT["model_n"] = model_n
    _ACT["anchor_residual"] = anchor_residual


def _mdl(dim_size: int):
    """'model' if the dim can shard over the model axis, else unconstrained."""
    n = _ACT["model_n"]
    return "model" if dim_size % n == 0 and dim_size >= n else _U


def constrain(x, *spec):
    """spec entries: 'model' | None (replicated) | _U (free); per dim."""
    if not _ACT["on"]:
        return x
    if not _ACT["anchor_residual"] and len(spec) == 3 and all(
            s is None or s is _U for s in spec):
        # the (B, L, d) residual / block-output anchors specifically;
        # 4-dim pins (e.g. head_dim = None) stay active in serving mode
        return x
    return jax.lax.with_sharding_constraint(x, _P(*spec))


def _einsum(subscripts, *args, dtype=None):
    """einsum with f32 accumulation, cast back to the first arg's dtype."""
    out_dtype = dtype or args[0].dtype
    return jnp.einsum(subscripts, *args,
                      preferred_element_type=f32).astype(out_dtype)


# ==========================================================================
# Norms
# ==========================================================================

def rms_norm(x, scale, eps: float = 1e-6):
    xf = x.astype(f32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(f32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(f32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(f32) + bias.astype(f32)).astype(x.dtype)


def apply_norm(params: Dict, x, kind: str):
    if kind == "rmsnorm":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params["bias"])


def gated_rms_norm(y, z, scale, eps: float = 1e-6):
    """Mamba-2 output norm: RMSNorm(y * silu(z))."""
    yf = y.astype(f32) * jax.nn.silu(z.astype(f32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    out = yf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(f32))
    return out.astype(y.dtype)


# ==========================================================================
# Positions: RoPE (standard / partial / M-RoPE) and sinusoidal
# ==========================================================================

def rope_cos_sin(positions, dim: int, theta: float):
    """positions (...,) -> cos, sin of shape (..., dim // 2), float32."""
    half = dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=f32) / half)
    ang = positions.astype(f32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions, dim: int, theta: float, sections):
    """M-RoPE (Qwen2-VL): ``positions`` is (3, ...) — temporal, height,
    width ids.  Frequency bands are split into ``sections`` (half-dims
    summing to dim//2); band ``s`` rotates by the s-th position stream."""
    half = dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=f32) / half)
    # (3, ..., half)
    ang = positions.astype(f32)[..., None] * freqs
    chunks, off = [], 0
    for s_idx, s in enumerate(sections):
        chunks.append(ang[s_idx, ..., off:off + s])
        off += s
    ang = jnp.concatenate(chunks, axis=-1)     # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, fraction: float = 1.0):
    """x: (B, L, H, D); cos/sin: (B, L, half_rot) or (L, half_rot).

    Rotates the first ``fraction * D`` channels (pairwise split halves, the
    llama/neox convention); the rest pass through (StableLM partial rotary).
    """
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    while cos.ndim < x1.ndim:                  # broadcast over head axis
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1f, x2f = x1.astype(f32), x2.astype(f32)
    r1 = x1f * cos - x2f * sin
    r2 = x2f * cos + x1f * sin
    out = jnp.concatenate([r1.astype(x.dtype), r2.astype(x.dtype)], axis=-1)
    return jnp.concatenate([out, x_pass], axis=-1)


def sinusoidal_embedding(positions, dim: int):
    """Classic transformer sinusoid table for (B?, L) positions."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=f32) / half)
    ang = positions.astype(f32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ==========================================================================
# Attention core
# ==========================================================================

def _gqa_scores(q, k):
    """q (B,Lq,H,D), k (B,Lk,K,D) -> scores (B,K,H/K,Lq,Lk), f32."""
    B, Lq, H, D = q.shape
    K = k.shape[2]
    qg = q.reshape(B, Lq, K, H // K, D)
    return jnp.einsum("blkgd,bskd->bkgls", qg, k,
                      preferred_element_type=f32)


def attention(q, k, v, *, scale: float, mask):
    """Masked softmax attention with GQA head grouping.

    q: (B, Lq, H, D);  k, v: (B, Lk, K, Dk/Dv);  mask: broadcastable to
    (B, 1, 1, Lq, Lk) (True = attend).  Returns (B, Lq, H, Dv).
    """
    B, Lq, H, _ = q.shape
    K = k.shape[2]
    scores = _gqa_scores(q, k) * scale
    neg = jnp.asarray(-1e30, f32)
    scores = jnp.where(mask, scores, neg)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgls,bskd->blkgd", probs.astype(v.dtype), v,
                     preferred_element_type=f32).astype(v.dtype)
    return out.reshape(B, Lq, H * v.shape[-1])


def _pick_block(L: int, target: int = 1024) -> int:
    """Largest divisor of L that is <= target (prefers multiples of 128)."""
    best = 1
    for b in range(1, min(L, target) + 1):
        if L % b == 0:
            best = b
    return best


def flash_attention_jnp(q, k, v, *, scale: float, window: int = 0,
                        block_q: int = 1024, block_k: int = 1024):
    """Memory-sane causal attention: O(L * block) live scores instead of
    O(L^2).  Pure-JAX mirror of the Pallas flash kernel (DESIGN.md §5) —
    ``lax.map`` over query blocks (each checkpointed, so the backward pass
    recomputes scores instead of storing them) with an online-softmax scan
    over key blocks.

    q: (B, Lq, H, Dk);  k: (B, S, H, Dk);  v: (B, S, H, Dv) — MHA layout:
    GQA callers expand K/V to H heads first.  Splitting H into (kv_head,
    group) here would make the head axis un-shardable on the ``model``
    mesh axis and force XLA into full rematerialization; the expanded
    copy is cheap (O(S*H*D)) and keeps the head dim intact.
    Keys are contiguous from position 0 and Lq == S (train/prefill path).
    Returns (B, Lq, H, Dv).
    """
    B, Lq, H, Dk = q.shape
    S = k.shape[1]
    Dv = v.shape[-1]
    bq = _pick_block(Lq, block_q)
    bk = _pick_block(S, block_k)
    nq, nk = Lq // bq, S // bk

    qb = jnp.moveaxis(q.reshape(B, nq, bq, H, Dk), 1, 0)   # (nq, B, bq, H, Dk)
    kb = jnp.moveaxis(k.reshape(B, nk, bk, H, Dk), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bk, H, Dv), 1, 0)

    kpos = jnp.arange(nk * bk).reshape(nk, bk)

    @jax.checkpoint
    def one_q_block(args):
        qi, iq = args                                      # (B, bq, H, Dk)
        qi = constrain(qi, _U, _U, _mdl(H), None)
        qpos = iq * bq + jnp.arange(bq)

        def kv_step(carry, xs):
            mx, l, acc = carry
            kblk, vblk, kp = xs
            s = jnp.einsum("bqhd,bshd->bhqs", qi, kblk,
                           preferred_element_type=f32) * scale
            s = constrain(s, _U, _mdl(H), _U, _U)
            mask = kp[None, :] <= qpos[:, None]
            if window > 0:
                mask &= kp[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(mx, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(mx - m_new)
            l = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhqs,bshd->bhqd", p.astype(v.dtype), vblk,
                            preferred_element_type=f32)
            acc = acc * alpha[..., None] + pv
            return (m_new, l, acc), None

        init = (jnp.full((B, H, bq), -1e30, f32),
                jnp.zeros((B, H, bq), f32),
                jnp.zeros((B, H, bq, Dv), f32))
        (mx, l, acc), _ = jax.lax.scan(kv_step, init, (kb, vb, kpos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (B, H, bq, Dv)
        return jnp.moveaxis(out, 2, 1).astype(q.dtype)     # (B, bq, H, Dv)

    outs = jax.lax.map(one_q_block, (qb, jnp.arange(nq)))  # (nq, B, bq, H, Dv)
    return jnp.moveaxis(outs, 0, 1).reshape(B, Lq, H, Dv)


# sequence length above which the train/prefill path switches from dense
# masked attention to the blocked flash path
FLASH_THRESHOLD = 1024


def causal_mask(Lq: int, Lk: int, *, q_offset=0, window: int = 0):
    """(Lq, Lk) boolean mask; query i sits at absolute position
    ``q_offset + i``, key j at absolute position j.  ``window`` > 0 further
    restricts to the last ``window`` positions (sliding window)."""
    qpos = jnp.arange(Lq)[:, None] + q_offset
    kpos = jnp.arange(Lk)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


# ==========================================================================
# GQA attention block (dense / vlm / audio / hybrid-attn layers)
# ==========================================================================

def _pos_cos_sin(cfg, positions):
    if cfg.pos == "rope":
        rot = int(cfg.head_dim * cfg.rope_fraction)
        rot -= rot % 2
        return rope_cos_sin(positions, rot, cfg.rope_theta)
    if cfg.pos == "mrope":
        return mrope_cos_sin(positions, cfg.head_dim, cfg.rope_theta,
                             cfg.mrope_sections)
    return None, None


def ring_from_full(full, S: int):
    """Pack the last ``min(L, S)`` timesteps of a full-sequence tensor
    (B, L, ...) into a ring buffer of size S:  absolute position p lives at
    slot ``p % S``.  Static shapes — indices resolved at trace time."""
    B, Lf = full.shape[0], full.shape[1]
    keep = min(Lf, S)
    p0 = Lf - keep
    ring = jnp.zeros((B, S) + full.shape[2:], full.dtype)
    slots = (p0 + jnp.arange(keep)) % S
    return ring.at[:, slots].set(full[:, p0:])


def attn_block_apply(params, cfg, x, *, positions, cache=None,
                     cache_pos=None, max_seq: int = 0):
    """One attention layer (projections + rope + cache + attention + out).

    Train/prefill: ``cache is None`` -> full causal (+window) attention
    over ``x`` (B, L, d); with ``max_seq > 0`` (prefill) the returned cache
    is a ring buffer of that size, otherwise the raw (L-long) k/v.
    Decode: ``cache`` = {"k","v"} ring/full buffers, ``cache_pos`` scalar
    absolute position of the incoming token; L == 1.
    """
    B, L, _ = x.shape
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    q = _einsum("bld,dhq->blhq", x,
                params["wq"].reshape(cfg.d_model, H, Dh))
    k = _einsum("bld,dkq->blkq", x,
                params["wk"].reshape(cfg.d_model, K, Dh))
    v = _einsum("bld,dkq->blkq", x,
                params["wv"].reshape(cfg.d_model, K, Dh))
    # head_dim pinned to None (replicated): when H doesn't divide the
    # model axis XLA otherwise factorizes the fused H*Dh dim as
    # (heads x head_dim) shards, making attention contract a sharded
    # D => one psum per flash block (55 TB/device on deepseek-coder
    # prefill; EXPERIMENTS.md §Perf)
    q = constrain(q, _U, _U, _mdl(H), None)
    k = constrain(k, _U, _U, _mdl(K), None)
    v = constrain(v, _U, _U, _mdl(K), None)

    cos, sin = _pos_cos_sin(cfg, positions)
    if cos is not None:
        q = apply_rope(q, cos, sin, cfg.rope_fraction)
        k = apply_rope(k, cos, sin, cfg.rope_fraction)

    scale = 1.0 / math.sqrt(Dh)
    window = cfg.window if cfg.attn == "sliding" else 0

    if cache is None:
        if L >= FLASH_THRESHOLD:
            kx = jnp.repeat(k, H // K, axis=2)      # expand GQA -> MHA so
            vx = jnp.repeat(v, H // K, axis=2)      # the head dim shards
            kx = constrain(kx, _U, _U, _mdl(H), None)
            vx = constrain(vx, _U, _U, _mdl(H), None)
            out = flash_attention_jnp(q, kx, vx, scale=scale, window=window)
            out = out.reshape(B, L, H * Dh)
        else:
            mask = causal_mask(L, L, window=window)[None, None, None]
            out = attention(q, k, v, scale=scale, mask=mask)
        if max_seq > 0:
            S = min(max_seq, window) if window > 0 else max_seq
            new_cache = {"k": ring_from_full(k, S),
                         "v": ring_from_full(v, S)}
        else:
            new_cache = ()
    else:
        S = cache["k"].shape[1]                # ring size (or max seq)
        slot = cache_pos % S
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        # absolute position held by ring slot j after the write:
        #   abs_j = cache_pos - ((cache_pos - j) mod S)   in (cache_pos-S, cache_pos]
        j = jnp.arange(S)
        abs_j = cache_pos - ((cache_pos - j) % S)
        valid = abs_j >= 0
        if window > 0:
            valid &= abs_j > cache_pos - window
        mask = valid[None, None, None, None, :]
        out = attention(q, ck, cv, scale=scale, mask=mask)
        new_cache = {"k": ck, "v": cv}

    out = _einsum("blf,fd->bld", out, params["wo"])
    out = constrain(out, _U, _U, None)
    return out, new_cache


def attn_block_init(key, cfg, init_scale=0.02):
    H, K, Dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    pd = cfg.param_dtype
    mk = lambda k, shape: (init_scale * jax.random.normal(k, shape)).astype(pd)
    return {
        "wq": mk(k1, (d, H * Dh)),
        "wk": mk(k2, (d, K * Dh)),
        "wv": mk(k3, (d, K * Dh)),
        "wo": mk(k4, (H * Dh, d)),
    }


def attn_cache_init(cfg, batch: int, max_seq: int, dtype):
    S = max_seq if cfg.attn != "sliding" else min(max_seq, cfg.window)
    K, Dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, S, K, Dh), dtype),
        "v": jnp.zeros((batch, S, K, Dh), dtype),
    }


# ==========================================================================
# MLA block (DeepSeek-V2)
# ==========================================================================

def mla_block_apply(params, cfg, x, *, positions, cache=None,
                    cache_pos=None, max_seq: int = 0):
    """Multi-head latent attention (DeepSeek-V2).

    Caches the compressed ``c_kv`` (kv_lora_rank) and the shared roped key
    ``k_rope`` — the order-of-magnitude-smaller decode cache that defines
    MLA.

    TPU adaptation (DESIGN.md §4): the *train/prefill* path expands
    per-head keys/values from the latent and runs the blocked flash path
    (cheapest FLOPs; expansion is O(L), fine when scores are blocked);
    the *decode* path uses **weight absorption** — queries are pushed
    through W_uk ("q_lat = q_nope W_uk") and attention runs directly
    against the latent cache, so no (B, S, H, dn) expansion of a 32k+
    cache ever materializes.
    """
    B, L, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank

    # --- queries (optionally low-rank) -----------------------------------
    if cfg.q_lora_rank > 0:
        cq = _einsum("bld,dq->blq", x, params["w_dq"])
        cq = rms_norm(cq, params["q_norm_scale"])
        q = _einsum("blq,qhf->blhf", cq,
                    params["w_uq"].reshape(cfg.q_lora_rank, H, dn + dr))
    else:
        q = _einsum("bld,dhf->blhf", x,
                    params["w_uq"].reshape(d, H, dn + dr))
    q = constrain(q, _U, _U, _mdl(H), None)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    # --- compressed kv ----------------------------------------------------
    c_kv = _einsum("bld,dq->blq", x, params["w_dkv"])
    c_kv = rms_norm(c_kv, params["kv_norm_scale"])
    k_rope = _einsum("bld,dr->blr", x, params["w_kr"])    # shared per token

    cos, sin = rope_cos_sin(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]

    scale = 1.0 / math.sqrt(dn + dr)
    w_uk = params["w_uk"].reshape(r, H, dn)
    w_uv = params["w_uv"].reshape(r, H, dv)

    if cache is None:
        # ---- train / prefill: expanded per-head K/V + flash --------------
        k_nope = constrain(_einsum("bsq,qhf->bshf", c_kv, w_uk),
                           _U, _U, _mdl(H), None)
        value = constrain(_einsum("bsq,qhf->bshf", c_kv, w_uv),
                          _U, _U, _mdl(H), None)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, L, H, dr)).astype(k_nope.dtype)],
            axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope.astype(q_nope.dtype)],
                                 axis=-1)
        if L >= FLASH_THRESHOLD:
            out = flash_attention_jnp(q_full, k_full, value, scale=scale)
        else:
            mask = causal_mask(L, L)[None, None, None]
            out = attention(q_full, k_full, value, scale=scale, mask=mask)
            out = out.reshape(B, L, H, dv)
        if max_seq > 0:
            new_cache = {"c_kv": ring_from_full(c_kv, max_seq),
                         "k_rope": ring_from_full(k_rope, max_seq)}
        else:
            new_cache = ()
    else:
        # ---- decode: absorbed attention against the latent cache ---------
        S = cache["c_kv"].shape[1]
        slot = cache_pos % S
        c_kv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv, slot, axis=1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope, slot, axis=1)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        j = jnp.arange(S)
        abs_j = cache_pos - ((cache_pos - j) % S)
        mask = (abs_j >= 0)[None, None, None, :]           # (1,1,1,S)

        q_lat = _einsum("blhn,rhn->blhr", q_nope, w_uk)    # absorb W_uk
        scores = (
            jnp.einsum("blhr,bsr->bhls", q_lat, c_kv,
                       preferred_element_type=f32)
            + jnp.einsum("blhr,bsr->bhls", q_rope, k_rope,
                         preferred_element_type=f32)
        ) * scale
        scores = constrain(scores, _U, _mdl(H), _U, _U)
        scores = jnp.where(mask, scores, jnp.asarray(-1e30, f32))
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o_lat = _einsum("bhls,bsr->blhr", probs, c_kv)
        out = _einsum("blhr,rhv->blhv", o_lat, w_uv)       # absorb W_uv

    out = out.reshape(B, L, H * dv)
    out = _einsum("blf,fd->bld", out, params["wo"])
    out = constrain(out, _U, _U, None)
    return out, new_cache


def mla_block_init(key, cfg, init_scale=0.02):
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 7)
    pd = cfg.param_dtype
    mk = lambda k, shape: (init_scale * jax.random.normal(k, shape)).astype(pd)
    p = {
        "w_dkv": mk(ks[0], (d, cfg.kv_lora_rank)),
        "kv_norm_scale": jnp.zeros((cfg.kv_lora_rank,), pd),
        "w_uk": mk(ks[1], (cfg.kv_lora_rank, H * dn)),
        "w_uv": mk(ks[2], (cfg.kv_lora_rank, H * dv)),
        "w_kr": mk(ks[3], (d, dr)),
        "wo": mk(ks[4], (H * dv, d)),
    }
    if cfg.q_lora_rank > 0:
        p["w_dq"] = mk(ks[5], (d, cfg.q_lora_rank))
        p["q_norm_scale"] = jnp.zeros((cfg.q_lora_rank,), pd)
        p["w_uq"] = mk(ks[6], (cfg.q_lora_rank, H * (dn + dr)))
    else:
        p["w_uq"] = mk(ks[6], (d, H * (dn + dr)))
    return p


def mla_cache_init(cfg, batch: int, max_seq: int, dtype):
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype),
    }


# ==========================================================================
# MLPs
# ==========================================================================

def mlp_apply(params, kind: str, x):
    ff = params["w_up"].shape[-1]
    spec = (_U,) * (x.ndim - 1) + (_mdl(ff),)
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        gate = act(constrain(_einsum("bld,df->blf", x, params["w_gate"],
                                     dtype=f32), *spec))
        up = constrain(_einsum("bld,df->blf", x, params["w_up"], dtype=f32),
                       *spec)
        h = (gate * up).astype(x.dtype)
    else:  # plain gelu
        h = jax.nn.gelu(constrain(
            _einsum("bld,df->blf", x, params["w_up"], dtype=f32),
            *spec)).astype(x.dtype)
    out = _einsum("blf,fd->bld", h, params["w_down"])
    return constrain(out, _U, _U, None)


def mlp_init(key, kind: str, d: int, d_ff: int, param_dtype,
             init_scale=0.02):
    ks = jax.random.split(key, 3)
    mk = lambda k, shape: (init_scale * jax.random.normal(k, shape)
                           ).astype(param_dtype)
    if kind in ("swiglu", "geglu"):
        return {"w_gate": mk(ks[0], (d, d_ff)),
                "w_up": mk(ks[1], (d, d_ff)),
                "w_down": mk(ks[2], (d_ff, d))}
    return {"w_up": mk(ks[0], (d, d_ff)), "w_down": mk(ks[1], (d_ff, d))}


# ==========================================================================
# MoE (capacity-based argsort dispatch, expert-parallel friendly)
# ==========================================================================

def moe_apply(params, cfg, x):
    """Top-k routed experts + optional shared experts.

    Dispatch: flatten (token, k) assignments, stable-argsort by expert id,
    compute each assignment's rank within its expert via searchsorted
    (no (T, E, C) one-hot), drop beyond capacity, scatter into an
    (E * C, d) buffer, run the batched expert einsum, gather back weighted.

    Returns (y, aux_loss) — aux is the switch-style load-balance loss.
    """
    B, L, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * L
    cap = max(1, int(cfg.capacity_factor * T * K / E))

    xt = x.reshape(T, d)
    logits = _einsum("td,de->te", xt, params["router"], dtype=f32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                 # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch): E * sum_e f_e * P_e
    me = probs.mean(axis=0)                                # (E,)
    ce = jnp.zeros((E,), f32).at[top_e.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    # --- dispatch ----------------------------------------------------------
    flat_e = top_e.reshape(-1)                             # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank of each assignment within its expert group
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(T * K) - first
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, E * cap)  # overflow bin
    token = order // K

    buf = jnp.zeros((E * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(xt[token], mode="drop")
    hidden = buf[:E * cap].reshape(E, cap, d)
    hidden = constrain(hidden, _mdl(E), _U, None)

    # --- expert compute (batched einsum; shards over E = model axis) ------
    gate = jax.nn.silu(constrain(
        jnp.einsum("ecd,edf->ecf", hidden, params["w_gate"],
                   preferred_element_type=f32), _mdl(E), _U, _U))
    up = constrain(jnp.einsum("ecd,edf->ecf", hidden, params["w_up"],
                              preferred_element_type=f32), _mdl(E), _U, _U)
    out = jnp.einsum("ecf,efd->ecd", (gate * up).astype(x.dtype),
                     params["w_down"], preferred_element_type=f32
                     ).astype(x.dtype)
    out = constrain(out, _mdl(E), _U, None)

    # --- combine -----------------------------------------------------------
    out_flat = out.reshape(E * cap, d)
    gathered = jnp.where(keep[:, None],
                         out_flat[jnp.minimum(slot, E * cap - 1)],
                         jnp.zeros((1, d), x.dtype))       # (T*K, d)
    weights = top_p.reshape(-1)[order]
    y = jnp.zeros((T, d), f32).at[token].add(
        gathered.astype(f32) * weights[:, None])

    if cfg.n_shared_experts > 0:
        y = y + mlp_apply(params["shared"], "swiglu", x).reshape(T, d)

    return y.reshape(B, L, d).astype(x.dtype), aux


def moe_init(key, cfg, init_scale=0.02):
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 5)
    pd = cfg.param_dtype
    mk = lambda k, shape: (init_scale * jax.random.normal(k, shape)).astype(pd)
    p = {
        "router": mk(ks[0], (d, E)).astype(f32),   # router in f32
        "w_gate": mk(ks[1], (E, d, ff)),
        "w_up": mk(ks[2], (E, d, ff)),
        "w_down": mk(ks[3], (E, ff, d)),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = mlp_init(ks[4], "swiglu", d,
                               cfg.n_shared_experts * ff, pd, init_scale)
    return p


# ==========================================================================
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# ==========================================================================

_RGLRU_C = 8.0


def _rglru_scan(a, b, h0=None):
    """h_t = a_t * h_{t-1} + b_t  along axis 1, via associative scan.
    a, b: (B, L, D) f32.  Returns (h (B, L, D), h_last (B, D))."""
    if h0 is not None:
        # fold the initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv, width W.  x: (B, L, C), w: (W, C).
    ``state``: (B, W-1, C) trailing context for decode; returns
    (y, new_state)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, L+W-1, C)
    yf = jnp.zeros(x.shape, f32)
    for i in range(W):
        yf = yf + xp[:, i:i + x.shape[1]].astype(f32) * w[i].astype(f32)
    y = (yf + b.astype(f32)).astype(x.dtype)
    new_state = xp[:, -(W - 1):] if W > 1 else pad
    return y, new_state


def rglru_block_apply(params, cfg, x, *, cache=None):
    """Griffin recurrent block: conv -> RG-LRU, gated by a GeLU branch.

    cache (decode): {"conv": (B, W-1, lru), "h": (B, lru)}.
    """
    B, L, d = x.shape
    lru = cfg.lru_width

    branch = constrain(_einsum("bld,df->blf", x, params["w_x"]),
                       _U, _U, _mdl(lru))
    gate_branch = jax.nn.gelu(constrain(
        _einsum("bld,df->blf", x, params["w_y"], dtype=f32),
        _U, _U, _mdl(lru)))

    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(branch, params["conv_w"], params["conv_b"],
                               conv_state)

    uf = u.astype(f32)
    r = jax.nn.sigmoid(_einsum("blf,fg->blg", u, params["w_r"], dtype=f32)
                       + params["b_r"].astype(f32))
    i = jax.nn.sigmoid(_einsum("blf,fg->blg", u, params["w_i"], dtype=f32)
                       + params["b_i"].astype(f32))
    log_a_base = jax.nn.log_sigmoid(params["a_param"].astype(f32))
    log_a = _RGLRU_C * r * log_a_base                 # (B, L, lru), <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * (i * uf)

    h0 = cache["h"].astype(f32) if cache is not None else None
    h, h_last = _rglru_scan(a, b, h0)

    out = (h * gate_branch).astype(x.dtype)
    out = _einsum("blf,fd->bld", out, params["w_o"])
    out = constrain(out, _U, _U, None)
    new_cache = {"conv": new_conv, "h": h_last.astype(x.dtype)}
    return out, new_cache


def rglru_block_init(key, cfg, init_scale=0.02):
    d, lru = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 7)
    pd = cfg.param_dtype
    mk = lambda k, shape: (init_scale * jax.random.normal(k, shape)).astype(pd)
    # a_param initialized so that a^c is in [0.9, 0.999] (Griffin)
    u = jax.random.uniform(ks[5], (lru,), f32, 0.9, 0.999)
    a_param = jnp.log(u ** (1.0 / _RGLRU_C) / (1 - u ** (1.0 / _RGLRU_C)))
    return {
        "w_x": mk(ks[0], (d, lru)),
        "w_y": mk(ks[1], (d, lru)),
        "conv_w": mk(ks[2], (cfg.d_conv, lru)),
        "conv_b": jnp.zeros((lru,), pd),
        "w_r": mk(ks[3], (lru, lru)),
        "b_r": jnp.zeros((lru,), pd),
        "w_i": mk(ks[4], (lru, lru)),
        "b_i": jnp.zeros((lru,), pd),
        "a_param": a_param.astype(f32),
        "w_o": mk(ks[6], (lru, d)),
    }


def rglru_cache_init(cfg, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.lru_width), dtype),
        "h": jnp.zeros((batch, cfg.lru_width), dtype),
    }


# ==========================================================================
# Mamba-2 SSD block
# ==========================================================================

def _segsum(x):
    """x (..., K) -> (..., K, K) lower-triangular inclusive-of-diagonal
    cumulative sums: out[i, j] = sum_{j < t <= i} x[t]  (0 on diagonal,
    -inf above)."""
    K = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((K, K), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD (Dao & Gu 2024, 'minimal' algorithm).

    x:  (b, l, h, p)   inputs per head
    dt: (b, l, h)      discretization steps (post-softplus)
    A:  (h,)           negative decay rates
    Bm, Cm: (b, l, g, n)   input/output projections (g groups)
    Returns y (b, l, h, p), final_state (b, h, p, n).
    """
    b, l, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert l % chunk == 0, (l, chunk)
    c = l // chunk
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=2)            # (b, l, h, n)
    Ch = jnp.repeat(Cm, rep, axis=2)

    # chunked views
    xc = x.reshape(b, c, chunk, h, p).astype(f32)
    dtc = dt.reshape(b, c, chunk, h).astype(f32)
    Bc = Bh.reshape(b, c, chunk, h, n).astype(f32)
    Cc = Ch.reshape(b, c, chunk, h, n).astype(f32)

    dtA = dtc * A.astype(f32)                   # (b, c, k, h)
    dtA_h = jnp.moveaxis(dtA, -1, -2)           # (b, c, h, k)
    L = jnp.exp(_segsum(dtA_h))                 # (b, c, h, k, k)

    xdt = xc * dtc[..., None]                   # (b, c, k, h, p)

    # intra-chunk (diagonal) term
    y_diag = jnp.einsum("bckhn,bclhn,bchkl,bclhp->bckhp", Cc, Bc, L, xdt)

    # per-chunk input states
    cum = jnp.cumsum(dtA_h, axis=-1)            # (b, c, h, k)
    total = cum[..., -1:]                       # (b, c, h, 1)
    decay_to_end = jnp.exp(total - cum)         # (b, c, h, k)
    states = jnp.einsum("bclhn,bchl,bclhp->bchpn", Bc, decay_to_end, xdt)

    # inter-chunk recurrence over c
    chunk_decay = jnp.exp(total[..., 0])        # (b, c, h)
    s0 = (jnp.zeros((b, h, p, n), f32) if init_state is None
          else init_state.astype(f32))

    def step(s, inp):
        dec, st = inp
        s_new = s * dec[..., None, None] + st
        return s_new, s

    xs = (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0))
    final, prev_states = jax.lax.scan(step, s0, xs)
    prev_states = jnp.moveaxis(prev_states, 0, 1)   # (b, c, h, p, n)

    # contribution of the carried-in state
    state_decay = jnp.exp(cum)                  # (b, c, h, k)
    y_off = jnp.einsum("bckhn,bchpn,bchk->bckhp", Cc, prev_states,
                       state_decay)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y.astype(x.dtype), final


def ssd_decode_step(state, x, dt, A, Bm, Cm):
    """Single-token SSD update.  x: (b, h, p), dt: (b, h), Bm/Cm: (b, g, n).
    state: (b, h, p, n) -> new state, y (b, h, p)."""
    g = Bm.shape[1]
    rep = x.shape[1] // g
    Bh = jnp.repeat(Bm, rep, axis=1).astype(f32)       # (b, h, n)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(f32)
    dtf = dt.astype(f32)
    dA = jnp.exp(dtf * A.astype(f32))                  # (b, h)
    xdt = x.astype(f32) * dtf[..., None]               # (b, h, p)
    new_state = (state.astype(f32) * dA[..., None, None]
                 + xdt[..., None] * Bh[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return new_state.astype(state.dtype), y.astype(x.dtype)


def mamba2_block_apply(params, cfg, x, *, cache=None):
    """Mamba-2 mixer.  cache (decode): {"conv": (B, W-1, convw),
    "ssm": (B, h, p, n)}."""
    B, L, d = x.shape
    di = cfg.d_inner
    h, p = cfg.n_ssm_heads, cfg.ssm_head_dim
    g, n = cfg.n_groups, cfg.d_state

    zxbcdt = constrain(_einsum("bld,df->blf", x, params["in_proj"]),
                       _U, _U, _U)
    z, xin, Braw, Craw, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)

    conv_in = jnp.concatenate([xin, Braw, Craw], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"],
                                      params["conv_b"], conv_state)
    conv_out = jax.nn.silu(conv_out.astype(f32)).astype(x.dtype)
    xs, Braw, Craw = jnp.split(conv_out, [di, di + g * n], axis=-1)

    xs = xs.reshape(B, L, h, p)
    Bm = Braw.reshape(B, L, g, n)
    Cm = Craw.reshape(B, L, g, n)
    dt = jax.nn.softplus(dt.astype(f32)
                         + params["dt_bias"].astype(f32))  # (B, L, h)
    A = -jnp.exp(params["A_log"].astype(f32))              # (h,)

    if cache is None:
        # pad to a chunk multiple
        pad = (-L) % cfg.ssm_chunk
        if pad:
            zp = lambda t: jnp.pad(t, [(0, 0), (0, pad)]
                                   + [(0, 0)] * (t.ndim - 2))
            xs, dt, Bm, Cm = map(zp, (xs, dt, Bm, Cm))
        y, final = ssd_scan(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
        y = y[:, :L]
        new_ssm = final.astype(x.dtype)
    else:
        new_ssm, y1 = ssd_decode_step(
            cache["ssm"], xs[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0])
        y = y1[:, None]

    y = y + xs[:, :y.shape[1]] * params["D"].astype(f32)[None, None, :, None
                                                         ].astype(x.dtype)
    y = y.reshape(B, L, di)
    y = gated_rms_norm(y, z, params["norm_scale"])
    out = _einsum("blf,fd->bld", y, params["out_proj"])
    out = constrain(out, _U, _U, None)
    new_cache = {"conv": new_conv, "ssm": new_ssm}
    return out, new_cache


def mamba2_block_init(key, cfg, init_scale=0.02):
    d, di = cfg.d_model, cfg.d_inner
    h = cfg.n_ssm_heads
    g, n = cfg.n_groups, cfg.d_state
    convw = di + 2 * g * n
    proj_out = 2 * di + 2 * g * n + h
    ks = jax.random.split(key, 4)
    pd = cfg.param_dtype
    mk = lambda k, shape: (init_scale * jax.random.normal(k, shape)).astype(pd)
    dt_init = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(ks[2], (h,), f32,
                                   jnp.log(1e-3), jnp.log(1e-1)))))
    return {
        "in_proj": mk(ks[0], (d, proj_out)),
        "conv_w": mk(ks[1], (cfg.d_conv, convw)),
        "conv_b": jnp.zeros((convw,), pd),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=f32)),
        "D": jnp.ones((h,), f32),
        "dt_bias": dt_init,
        "norm_scale": jnp.zeros((di,), pd),
        "out_proj": mk(ks[3], (di, d)),
    }


def mamba2_cache_init(cfg, batch: int, dtype):
    convw = cfg.d_inner + 2 * cfg.n_groups * cfg.d_state
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, convw), dtype),
        "ssm": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_head_dim,
                          cfg.d_state), dtype),
    }
