from repro.optim.optimizers import (   # noqa: F401
    OptimizerBundle, make_optimizer, global_norm, clip_by_global_norm)
from repro.optim.schedules import make_schedule   # noqa: F401
