"""Learning-rate schedules (pure functions of the step)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def make_schedule(cfg: TrainConfig):
    """Returns lr(step) -> f32 scalar."""
    base = jnp.float32(cfg.lr)

    def lr_fn(step):
        step = step.astype(jnp.float32)
        lr = base
        if cfg.schedule == "cosine":
            total = max(cfg.total_steps - cfg.warmup_steps, 1)
            frac = jnp.clip((step - cfg.warmup_steps) / total, 0.0, 1.0)
            lr = base * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        if cfg.warmup_steps > 0:
            warm = jnp.minimum(step / cfg.warmup_steps, 1.0)
            lr = lr * warm
        return lr

    return lr_fn
