"""Optimizers: SGD (+momentum, the paper's choice) and Adam.

Implemented as (init, update) pairs over parameter pytrees; ``update``
consumes the *aggregated* gradient produced by the safeguard (or by a
baseline aggregator) — the optimizer is deliberately decoupled from the
Byzantine layer.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.optim.schedules import make_schedule

f32 = jnp.float32


def global_norm(tree) -> jax.Array:
    # elementwise square + reduce (vdot's flattening reshape would break
    # multi-axis sharding and gather the full tensor)
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(f32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(f32) * scale).astype(g.dtype),
                        tree), norm


@dataclasses.dataclass(frozen=True)
class OptimizerBundle:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple]
    """update(grads, opt_state, params, step) -> (new_params, new_state)"""


def make_optimizer(cfg: TrainConfig) -> OptimizerBundle:
    lr_fn = make_schedule(cfg)

    if cfg.optimizer == "sgd":
        def init(params):
            if cfg.momentum > 0.0:
                return {"mu": jax.tree.map(
                    lambda p: jnp.zeros(p.shape, f32), params)}
            return {}

        def update(grads, state, params, step):
            if cfg.grad_clip > 0.0:
                grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
            lr = lr_fn(step)
            if cfg.momentum > 0.0:
                mu = jax.tree.map(
                    lambda m, g: cfg.momentum * m + g.astype(f32),
                    state["mu"], grads)
                direction = mu
                new_state = {"mu": mu}
            else:
                direction = jax.tree.map(lambda g: g.astype(f32), grads)
                new_state = state
            def step_leaf(p, d):
                upd = lr * d
                if cfg.weight_decay > 0.0:
                    upd = upd + lr * cfg.weight_decay * p.astype(f32)
                return (p.astype(f32) - upd).astype(p.dtype)
            return jax.tree.map(step_leaf, params, direction), new_state

        return OptimizerBundle(init, update)

    if cfg.optimizer == "adam":
        b1, b2, eps = 0.9, 0.999, 1e-8

        def init(params):
            z = lambda p: jnp.zeros(p.shape, f32)
            return {"m": jax.tree.map(z, params),
                    "v": jax.tree.map(z, params),
                    "count": jnp.zeros((), jnp.int32)}

        def update(grads, state, params, step):
            if cfg.grad_clip > 0.0:
                grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
            count = state["count"] + 1
            lr = lr_fn(step)
            m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(f32),
                             state["m"], grads)
            v = jax.tree.map(
                lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(f32)),
                state["v"], grads)
            c1 = 1 - b1 ** count.astype(f32)
            c2 = 1 - b2 ** count.astype(f32)

            def step_leaf(p, m_, v_):
                upd = lr * (m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
                if cfg.weight_decay > 0.0:
                    upd = upd + lr * cfg.weight_decay * p.astype(f32)
                return (p.astype(f32) - upd).astype(p.dtype)
            new_params = jax.tree.map(step_leaf, params, m, v)
            return new_params, {"m": m, "v": v, "count": count}

        return OptimizerBundle(init, update)

    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")
