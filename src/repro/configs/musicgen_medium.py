"""musicgen-medium [audio] — decoder-only transformer over EnCodec tokens.

48L d_model=1536 24H (kv=24, full MHA) d_ff=6144 vocab=2048.
[arXiv:2306.05284]  The EnCodec frontend is a stub: ``input_specs`` provides
precomputed frame embeddings (B, L, d); the backbone predicts codec tokens.
MusicGen uses LayerNorm + GELU and sinusoidal positions.
"""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    norm="layernorm",
    mlp="gelu",
    pos="sinusoidal",
    embed_stub=True,
    source="arXiv:2306.05284",
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="musicgen-medium-smoke",
    arch_type="audio",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab_size=256,
    norm="layernorm",
    mlp="gelu",
    pos="sinusoidal",
    embed_stub=True,
    source="arXiv:2306.05284",
)
