"""granite-34b [dense] — code model with MQA.

88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152. [arXiv:2405.04324]
GELU MLP (d_ff = 4*d, GPTBigCode lineage) — the swiglu variant would put
the parameter count at 47B instead of the model's 34B.
"""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    arch_type="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp="gelu",
    norm="layernorm",
    source="arXiv:2405.04324",
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="granite-34b-smoke",
    arch_type="dense",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=1,
    d_ff=512,
    vocab_size=256,
    mlp="gelu",
    norm="layernorm",
    source="arXiv:2405.04324",
)
