"""deepseek-v2-236b [moe] — MLA attention + fine-grained MoE.

60L d_model=5120 128H, MLA kv_lora=512 (q_lora=1536, qk_nope=128,
qk_rope=64, v=128), MoE: 2 shared + 160 routed experts, top-6,
d_expert=1536, first layer dense FFN (12288). vocab=102400.
[arXiv:2405.04434]
"""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,                    # per the assignment row (= expert width)
    vocab_size=102400,
    head_dim=192,                 # qk_nope + qk_rope
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    d_expert=1536,
    first_k_dense=1,
    d_ff_dense=12288,
    source="arXiv:2405.04434",
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke",
    arch_type="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab_size=256,
    head_dim=48,
    use_mla=True,
    q_lora_rank=64,
    kv_lora_rank=32,
    qk_nope_dim=32,
    qk_rope_dim=16,
    v_head_dim=32,
    n_experts=4,
    n_shared_experts=1,
    top_k=2,
    d_expert=64,
    first_k_dense=1,
    d_ff_dense=256,
    source="arXiv:2405.04434",
)
