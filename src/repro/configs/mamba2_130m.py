"""mamba2-130m [ssm] — attention-free SSD (state-space duality).

24L d_model=768, d_state=128, expand=2 (d_inner=1536, 24 heads of dim 64),
depthwise conv 4, vocab=50280.  [arXiv:2405.21060]
"""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=True,
    d_state=128,
    d_conv=4,
    expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    n_groups=1,
    tie_embeddings=True,
    source="arXiv:2405.21060",
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    arch_type="ssm",
    n_layers=2,
    d_model=128,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=256,
    ssm=True,
    d_state=32,
    d_conv=4,
    expand=2,
    ssm_head_dim=32,
    ssm_chunk=16,
    n_groups=1,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
