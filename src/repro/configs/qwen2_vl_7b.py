"""qwen2-vl-7b [vlm] — language backbone with M-RoPE.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064. M-RoPE sections
(temporal/height/width) = (16, 24, 24) half-dims of head_dim 128; dynamic
resolution handled by the (stubbed) ViT frontend — ``input_specs`` provides
merged patch+text embeddings (B, L, d) and 3-axis position ids (3, B, L).
[arXiv:2409.12191]
"""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    arch_type="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    pos="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    embed_stub=True,
    source="arXiv:2409.12191",
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke",
    arch_type="vlm",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=256,
    pos="mrope",
    mrope_sections=(4, 6, 6),
    embed_stub=True,
    source="arXiv:2409.12191",
)
