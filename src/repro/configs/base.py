"""Model / training / mesh configuration dataclasses.

Every assigned architecture gets one module in this package defining
``CONFIG`` (the exact full-scale configuration from the assignment table)
and ``SMOKE`` (a reduced same-family variant: <=2 layers, d_model <= 512,
<=4 experts) used by the CPU smoke tests.  ``repro.configs.get(name)``
resolves either.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    source: str = ""               # citation from the assignment table

    # normalization / mlp / positional flavor
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    mlp: str = "swiglu"            # swiglu | geglu | gelu
    pos: str = "rope"              # rope | mrope | sinusoidal | none
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0     # stablelm: partial rotary (0.25)
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl: (16, 24, 24) half-dims
    tie_embeddings: bool = False

    # attention
    attn: str = "full"             # full | sliding
    window: int = 0                # sliding-window size (attn == "sliding")
    attn_logit_softcap: float = 0.0

    # modality frontend stub (vlm/audio): inputs are precomputed embeddings
    embed_stub: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    first_k_dense: int = 0         # deepseek-v2: first layer(s) use dense FFN
    d_ff_dense: int = 0            # dense-FFN width for those layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # MLA (deepseek-v2)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2 / SSD)
    ssm: bool = False
    d_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    n_groups: int = 1

    # hybrid (recurrentgemma): layer i is attention iff (i % 3 == 2)
    hybrid: bool = False
    lru_width: int = 0

    # numerics
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:        # ssm inner width
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the 524k-token decode shape."""
        return self.ssm or self.hybrid or self.attn == "sliding"

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        import math
        from repro.models.transformer import init_abstract
        import jax
        shapes = init_abstract(self)
        return sum(math.prod(l.shape)
                   for l in jax.tree_util.tree_leaves(shapes))

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: routed top_k of n_experts,
        shared experts and everything else fully active)."""
        if self.n_experts == 0:
            return self.param_count()
        import math
        from repro.models.transformer import init_abstract
        import jax
        shapes = init_abstract(self)
        total = 0
        routed = ("w_gate", "w_up", "w_down")
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            n = math.prod(leaf.shape)
            keys = [str(getattr(p, "key", p)) for p in path]
            if "moe" in keys and keys[-1] in routed and "shared" not in keys:
                n = n * self.top_k // self.n_experts
            total += n
        return total


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned (seq_len, global_batch, kind) tuples."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 0.1
    momentum: float = 0.0
    weight_decay: float = 0.0
    optimizer: str = "sgd"          # sgd | adam
    warmup_steps: int = 0
    schedule: str = "constant"      # constant | cosine
    total_steps: int = 1000
    grad_clip: float = 0.0
    seed: int = 0
