"""tinyllama-1.1b [dense] — llama2-arch small.

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000. [arXiv:2401.02385]
"""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    arch_type="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    source="arXiv:2401.02385",
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="tinyllama-smoke",
    arch_type="dense",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=384,
    vocab_size=256,
    source="arXiv:2401.02385",
)
