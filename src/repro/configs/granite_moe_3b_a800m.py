"""granite-moe-3b-a800m [moe] — llama-arch GQA + 40-expert top-8 MoE.

32L d_model=1536 24H (GQA kv=8) d_ff=512 (expert width) vocab=49155,
MoE 40e top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]
"""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    n_experts=40,
    top_k=8,
    d_expert=512,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="granite-moe-smoke",
    arch_type="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=256,
    n_experts=4,
    top_k=2,
    d_expert=64,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
