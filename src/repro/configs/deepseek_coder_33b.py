"""deepseek-coder-33b [dense] — llama-arch code model.

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256. [arXiv:2401.14196]
"""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    source="arXiv:2401.14196",
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="deepseek-coder-smoke",
    arch_type="dense",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=256,
    source="arXiv:2401.14196",
)
