"""Assigned-architecture registry.

``get(name)`` -> full ModelConfig;  ``get_smoke(name)`` -> reduced variant.
``ARCH_IDS`` lists the ten assigned architectures in assignment order.
"""

from __future__ import annotations

import importlib

from repro.configs.base import (   # noqa: F401
    ModelConfig, InputShape, INPUT_SHAPES, TrainConfig)

ARCH_IDS = [
    "musicgen-medium",
    "granite-34b",
    "deepseek-v2-236b",
    "granite-moe-3b-a800m",
    "qwen2-vl-7b",
    "deepseek-coder-33b",
    "recurrentgemma-2b",
    "tinyllama-1.1b",
    "stablelm-1.6b",
    "mamba2-130m",
]

# beyond-assignment variants (DESIGN.md §7)
EXTRA_IDS = ["tinyllama-1.1b-swa"]

_MODULES = {
    "musicgen-medium": "musicgen_medium",
    "granite-34b": "granite_34b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "stablelm-1.6b": "stablelm_1_6b",
    "mamba2-130m": "mamba2_130m",
    "tinyllama-1.1b-swa": "tinyllama_1_1b_swa",
}


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE
