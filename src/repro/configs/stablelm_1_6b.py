"""stablelm-1.6b [dense] — MHA with partial rotary embeddings.

24L d_model=2048 32H (kv=32, full MHA) d_ff=5632 vocab=100352,
rotary fraction 0.25, LayerNorm.  [hf:stabilityai/stablelm-2-1_6b]
"""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    norm="layernorm",
    rope_fraction=0.25,
    source="hf:stabilityai/stablelm-2-1_6b",
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="stablelm-smoke",
    arch_type="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=384,
    vocab_size=256,
    norm="layernorm",
    rope_fraction=0.25,
    source="hf:stabilityai/stablelm-2-1_6b",
)
