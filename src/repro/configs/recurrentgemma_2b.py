"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 ratio.

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, lru_width=2560,
local-attention window 2048, pattern (rec, rec, attn) — layer i is
attention iff i % 3 == 2.  GeGLU MLP.  [arXiv:2402.19427]
"""

import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    mlp="geglu",
    attn="sliding",
    window=2048,
    hybrid=True,
    lru_width=2560,
    tie_embeddings=True,
    source="arXiv:2402.19427",
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    arch_type="hybrid",
    n_layers=3,                    # one full (rec, rec, attn) pattern
    d_model=128,
    n_heads=4,
    n_kv_heads=1,
    d_ff=256,
    vocab_size=256,
    head_dim=32,
    mlp="geglu",
    attn="sliding",
    window=32,
    hybrid=True,
    lru_width=128,
    source="arXiv:2402.19427",
)
