"""tinyllama-1.1b-swa — sliding-window variant (beyond assignment).

Same architecture as tinyllama-1.1b with a 4096-token attention window so
the dense family can run the ``long_500k`` decode shape sub-quadratically
(DESIGN.md §7).
"""

import dataclasses

from repro.configs.tinyllama_1_1b import CONFIG as _BASE, SMOKE as _SMOKE

CONFIG = dataclasses.replace(
    _BASE, name="tinyllama-1.1b-swa", attn="sliding", window=4096)

SMOKE = dataclasses.replace(
    _SMOKE, name="tinyllama-swa-smoke", attn="sliding", window=32)
